//! Overlapped one-step async runtime (paper §2.1, Fig 7, §5.2).
//!
//! The paper's throughput claim rests on *hiding* synchronization inside
//! the generation window: while actors generate batch `s` on the stale
//! policy `v_{s-1}`, the Trainer Hub trains on batch `s-1`, extracts and
//! streams `D_{v_s}` into every actor's staging decoder mid-generation,
//! and Commit lands at each actor's next safe point (between generation
//! batches) — no global barrier. This module implements that schedule
//! twice over the *same* step logic:
//!
//! * [`ExecMode::Sequential`] — every phase in program order on one
//!   thread (the reference executor; wall-clock is the sum of phases);
//! * [`ExecMode::Pipelined`] — one worker per actor behind a
//!   [`Transport`] backend, with the hub training/streaming concurrently
//!   with generation.
//!
//! The pipelined executor is **transport-agnostic**: hub and workers
//! speak only `rt::net::Msg` through the `transport::api` handle types,
//! so the identical executor code path runs over in-process mailboxes
//! (`InProc`, the zero-copy default), the netsim WAN-reorder model
//! (`Sim`), and real loopback sockets (`Tcp`) — selected by
//! `LocalRunConfig::transport`. Failure is a first-class input: a dead
//! or partitioned actor surfaces as a transport `Down` event or a lease
//! expiry, its prompts requeue to survivors under fresh leases with the
//! *original* job's RNG seed (so regeneration is bit-reproducible), and
//! the run completes without a global restart — the paper's §5.4 loop.
//!
//! Both executors share `plan_step` / `run_gen_job` / `train_and_stream`,
//! draw per-(step, actor) RNG streams, and assemble training batches in
//! assignment order, so with `LocalRunConfig::deterministic` the two modes
//! — and all three transport backends — are **bit-identical**: same
//! committed policies, same per-step rho and payload bytes, same final
//! version (see `tests/pipeline_equivalence.rs` and
//! `tests/transport_equivalence.rs`). Bit-exactness of actor policies
//! against the trainer is asserted at every committed version via a
//! SHA-256 witness ([`policy_checksum`]) carried in the `Activated` ack.
//!
//! Why the overlap is legal: a generation job snapshots the actor's params
//! at job start, so a Commit applying between generation batches never
//! changes in-flight completions — it only moves the *next* job onto the
//! new version, exactly the paper's staged-activation contract.

use crate::actor::rollout::SampleCfg;
use crate::actor::{CommitResult, PolicyState};
use crate::config::GpuClass;
use crate::cost::{reserved_line, Autoscaler, Deployment};
use crate::data::{pack_batch, Task};
use crate::delta::{
    merge_chain, CheckpointStore, DeltaCheckpoint, DurableStore, JournalRecord, ModelLayout,
    ModelRegistry, ParamSet, ResumePoint, SeedRecord, SparseDelta, SwapPin,
};
use crate::ledger::{Clock, JobLedger, Reject};
use crate::metrics::{SpanKind, Timeline};
use crate::rt::compute::Compute;
use crate::rt::local::{
    BootstrapKind, FailReason, JoinSpec, LeaveSpec, LocalRunConfig, RunReport, StepLog,
    TransportKind,
};
use crate::rt::net::Msg;
use crate::runtime::TrainState;
use crate::scheduler::{Assignment, Scheduler, SchedulerConfig, VersionState};
use crate::session::{Event as SessionEvent, ReportAssembler, RunTail};
use crate::trainer::{group_advantages, stream_checkpoint, Rollout};
use crate::transport::api::{
    ActorEndpoint, Closed, Event, HubEndpoint, InProcTransport, Polled, SimTransport, Transport,
};
use crate::transport::tcp::TcpTransport;
use crate::transport::{split_into_segments, Segment};
use crate::util::Rng;
use anyhow::{anyhow, bail, ensure, Result};
use sha2::{Digest, Sha256};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Geo-distribution wiring for the runtime: actors grouped into regions,
/// one relay per region. The hub streams each delta segment once per
/// region — to the relay's mailbox — and the relay worker forwards it to
/// its regional peers cut-through, mirroring
/// [`crate::transport::DistributionPlan`]'s tree inside one process.
/// Commits still go hub→actor directly, so on multi-hop paths a
/// `Commit(v)` can overtake `D_v` segments; `PolicyState` parks such
/// commits until staging completes (see `actor::mod`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DistributionSpec {
    /// Region index of each actor, in actor order (empty = flat hub→all).
    pub region_of: Vec<usize>,
}

impl DistributionSpec {
    /// Derive the runtime wiring from a transport-layer plan.
    pub fn from_plan(plan: &crate::transport::DistributionPlan) -> DistributionSpec {
        DistributionSpec { region_of: plan.region_map() }
    }

    pub fn is_flat(&self) -> bool {
        self.region_of.is_empty()
    }

    pub fn n_regions(&self) -> usize {
        self.region_of.iter().max().map_or(0, |m| m + 1)
    }

    /// The relay (first actor) of each region, by region index.
    pub fn relays(&self) -> Vec<usize> {
        (0..self.n_regions())
            .filter_map(|r| self.region_of.iter().position(|&x| x == r))
            .collect()
    }

    /// Actors relay `actor` forwards segments to: its region's non-relay
    /// members, when `actor` is that region's relay; empty otherwise.
    pub fn forward_targets(&self, actor: usize) -> Vec<usize> {
        let Some(&region) = self.region_of.get(actor) else {
            return Vec::new();
        };
        let relay = self.region_of.iter().position(|&x| x == region);
        if relay != Some(actor) {
            return Vec::new();
        }
        self.region_of
            .iter()
            .enumerate()
            .filter(|&(i, &r)| r == region && i != actor)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Executor choice for the local runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Phase-sequential reference executor (rollout, train, extract,
    /// commit in program order on one thread).
    Sequential,
    /// One worker thread per actor; training + delta streaming overlap
    /// generation; commits land at per-actor safe points.
    Pipelined,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Pipelined => "pipelined",
        }
    }
}

/// SHA-256 over the policy's bf16 bits in layout order — the witness the
/// pipelined runtime ships across threads to assert actor == trainer
/// bit-exactness at every committed version.
pub fn policy_checksum(p: &ParamSet) -> [u8; 32] {
    let mut h = Sha256::new();
    let mut buf: Vec<u8> = Vec::new();
    for t in &p.tensors {
        buf.clear();
        buf.reserve(t.len() * 2);
        for b in t {
            buf.extend_from_slice(&b.to_bits().to_le_bytes());
        }
        h.update(&buf);
    }
    h.finalize()
}

/// Independent RNG stream per (seed, step, actor): generation draws the
/// same randomness in both executors regardless of thread interleaving.
fn job_seed(seed: u64, step: u64, actor: u32) -> u64 {
    let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9).wrapping_add(step);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ ((actor as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// One actor's generation work for one step.
#[derive(Clone, Debug)]
struct GenJob {
    step: u64,
    /// Policy version the rollouts must be generated on (the lease's v).
    version: u64,
    /// Integrity hash of that version's checkpoint (the lease's h).
    hash: [u8; 32],
    /// Claimed prompt ids, in lease order.
    pids: Vec<u64>,
    rng_seed: u64,
}

// The hub↔actor protocol is `rt::net::Msg`, carried by whatever
// `transport::api` backend the config selects. Control-plane FIFO order
// (per actor) is the correctness backbone: a `Job` for version `v` is
// only dispatched after that actor's `Activated(v)` ack, so generation
// never starts on a version the actor hasn't applied — while segments
// may ride reordered paths freely (staging is order-insensitive, and a
// `Commit` overtaking its segments parks in `PolicyState`).

/// Run one generation job against `state`, serving completions from
/// `policy_ref` — the behaviour snapshot the caller resolved for
/// `job.version` via [`PolicyState::behaviour_policy`] (the active
/// policy, or the retained previous version when a commit already rolled
/// the actor forward mid-step). `at_safe_point` fires between generation
/// batches so staging and deferred commits can land mid-step without
/// touching in-flight output.
fn run_gen_job<C: Compute>(
    comp: &C,
    cfg: &LocalRunConfig,
    state: &mut PolicyState,
    policy_ref: &ParamSet,
    actor: u32,
    job: &GenJob,
    mut at_safe_point: impl FnMut(&mut PolicyState) -> Result<(), String>,
) -> Result<(Vec<Rollout>, u64), String> {
    let shape = comp.shape();
    let mut rng = Rng::new(job.rng_seed);
    let mut rollouts = Vec::with_capacity(job.pids.len() * cfg.group_size);
    let mut gen_tokens = 0u64;
    let sample = SampleCfg { temperature: cfg.temperature, max_new_tokens: cfg.max_new_tokens };
    for chunk in job.pids.chunks((shape.b_gen / cfg.group_size).max(1)) {
        state.set_generating(true);
        let mut prompts = Vec::with_capacity(chunk.len() * cfg.group_size);
        for &pid in chunk {
            let task = Task::from_prompt_id(pid, cfg.bench);
            for _ in 0..cfg.group_size {
                prompts.push(task.prompt_tokens());
            }
        }
        let gens = comp
            .generate(policy_ref, &prompts, sample, &mut rng)
            .map_err(|e| format!("actor {actor} generate: {e:#}"));
        state.set_generating(false);
        let gens = gens?;
        for (gi, g) in gens.iter().enumerate() {
            let pid = chunk[gi / cfg.group_size];
            let task = Task::from_prompt_id(pid, cfg.bench);
            let completion = &g.tokens[g.prompt_len..];
            gen_tokens += completion.len() as u64;
            rollouts.push(Rollout {
                prompt_id: pid,
                actor,
                version: job.version,
                prompt_tokens: g.tokens[..g.prompt_len].to_vec(),
                generated_tokens: completion.to_vec(),
                reward: task.reward(completion),
            });
        }
        // Inter-batch safe point: drain staging segments / commits.
        at_safe_point(state)?;
    }
    Ok((rollouts, gen_tokens))
}

/// Per-step record assembled across loop iterations (generation lands a
/// step before its training under the one-step-off schedule).
#[derive(Clone, Copy, Default)]
struct StepAccum {
    mean_reward: f32,
    gen_tokens: u64,
    rollout_ms: f64,
    loss: f32,
    train_ms: f64,
    extract_ms: f64,
    rho: f64,
    payload_bytes: u64,
    policy_checksum: [u8; 32],
}

/// Trainer-hub state shared by both executors.
struct Hub<'a, C: Compute> {
    cfg: &'a LocalRunConfig,
    layout: &'a ModelLayout,
    comp: &'a C,
    state: TrainState,
    /// Trainer policy snapshot at `version`.
    policy: ParamSet,
    version: u64,
    version_hash: [u8; 32],
    store: CheckpointStore,
    /// Content-addressed on-disk store (`LocalRunConfig::persist_dir`).
    /// When present, every commit seals its delta + optimizer state and
    /// appends a journal record *before* the version is observable, so a
    /// crash at any point resumes bit-exactly ([`DurableStore`]).
    durable: Option<DurableStore>,
    /// First step the executor loops run (nonzero only on resume).
    start_step: u64,
    /// The regenerated in-flight batch a resumed run trains first, in
    /// place of the executor's own `pending`/`last_batch` seed.
    resume_pending: Option<(u64, Vec<Rollout>)>,
    ledger: JobLedger,
    sched: Scheduler,
    /// Lease clock: wall time normally (leases genuinely expire on
    /// stalls); a manual µs-tick clock under `deterministic` without
    /// `wall_leases`, so leases never expire and every backend accepts
    /// identical rollout sets.
    clock: Clock,
    timeline: Timeline,
    /// RL-phase origin for timeline spans.
    t0: Instant,
    task_counter: u64,
    prompts_per_step: usize,
    accum: Vec<StepAccum>,
    /// Actors lost to crash/partition this run (lease-driven failover).
    failures: u64,
    /// Prompts re-leased to survivors after a failure.
    requeued: u64,
    /// Typed observation stream (the Session API's feed; the blocking
    /// legacy wrapper folds it straight into a report). Called only from
    /// the hub's thread.
    sink: &'a mut (dyn FnMut(SessionEvent) + 'a),
    /// Cooperative cancellation (`Session::abort`): checked at step
    /// boundaries and every collect-loop poll tick.
    cancel: &'a AtomicBool,
}

impl<'a, C: Compute> Hub<'a, C> {
    fn new(
        cfg: &'a LocalRunConfig,
        layout: &'a ModelLayout,
        comp: &'a C,
        state: TrainState,
        task_counter: u64,
        durable: Option<DurableStore>,
        sink: &'a mut (dyn FnMut(SessionEvent) + 'a),
        cancel: &'a AtomicBool,
    ) -> Hub<'a, C> {
        let policy = state.to_policy();
        let mut sched = Scheduler::new(SchedulerConfig::default());
        for i in 0..cfg.n_actors {
            sched.register(i as u32, 1000.0);
            sched.observe_version(i as u32, VersionState { active: 0, staged: None });
        }
        // Region tags / the bandwidth-aware allocation gate are not wired
        // here: in-process streaming has no per-region WAN timings to
        // observe (and feeding wall-clock stream durations would break the
        // deterministic executor-equivalence contract). The gate runs
        // where real link timings exist: the netsim driver
        // (`SimConfig::bandwidth_gate`) and `sparrowrl exp wan`.
        let clock = if cfg.deterministic && !cfg.wall_leases {
            Clock::manual(0.0)
        } else {
            Clock::wall()
        };
        Hub {
            cfg,
            layout,
            comp,
            state,
            policy,
            version: 0,
            // Version-0 "hash": the genesis policy has no checkpoint.
            version_hash: [0u8; 32],
            store: CheckpointStore::in_memory(),
            durable,
            start_step: 0,
            resume_pending: None,
            ledger: JobLedger::new(cfg.lease),
            sched,
            clock,
            timeline: Timeline::default(),
            t0: Instant::now(),
            task_counter,
            prompts_per_step: comp.shape().b_train / cfg.group_size,
            accum: vec![StepAccum::default(); cfg.steps as usize],
            failures: 0,
            requeued: 0,
            sink,
            cancel,
        }
    }

    fn emit(&mut self, ev: SessionEvent) {
        (self.sink)(ev);
    }

    /// Bail out at a cancellation point if `Session::abort` fired.
    fn check_cancel(&self) -> Result<()> {
        if self.cancel.load(Ordering::Relaxed) {
            bail!("{}", crate::session::ABORT_MSG);
        }
        Ok(())
    }

    fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Collect-loop poll granularity — the lease-expiry sweep interval,
    /// from [`crate::ledger::LeasePolicy::sweep_ms`] (spec validation
    /// rejects zero; clamp defensively for direct `LocalRunConfig`
    /// construction).
    fn poll_interval(&self) -> Duration {
        Duration::from_millis(self.cfg.lease.sweep_ms.max(1))
    }

    /// Lease timestamp: wall seconds normally; under the deterministic
    /// manual clock each read ticks 1 µs, so issue/submit stay ordered
    /// while leases (seconds-scale) never expire spuriously.
    fn lease_now(&mut self) -> f64 {
        self.clock.advance(1e-6);
        self.clock.now()
    }

    /// Post this step's prompts and lease them out per Algorithm 1,
    /// against the *current* committed version (one step stale relative
    /// to the version being trained concurrently).
    fn plan_step(&mut self, step: u64) -> Result<Vec<(Assignment, GenJob)>> {
        let (version, hash) = (self.version, self.version_hash);
        self.plan_step_at(step, version, hash)
    }

    /// [`Hub::plan_step`] against an explicit `(version, hash)` lease
    /// pair. Normal operation always plans at the hub's current version;
    /// a resumed run replays the crash-lost batch at the *previous*
    /// version (the one it was originally leased on) so the regenerated
    /// rollouts are bit-identical to the uninterrupted run's.
    fn plan_step_at(
        &mut self,
        step: u64,
        version: u64,
        hash: [u8; 32],
    ) -> Result<Vec<(Assignment, GenJob)>> {
        let pids: Vec<u64> = (0..self.prompts_per_step)
            .map(|_| {
                self.task_counter += 1;
                self.task_counter
            })
            .collect();
        self.ledger.post(pids.iter().copied());
        let now = self.lease_now();
        // Real-clock lease hygiene: reclaim anything overdue from stalled
        // or crashed in-flight work before allocating.
        self.ledger.expire(now);
        let assignments = self.sched.allocate(version, self.prompts_per_step as u64);
        if assignments.is_empty() {
            bail!("no eligible actors at step {step}");
        }
        let mut out = Vec::with_capacity(assignments.len());
        for asg in assignments {
            let claimed = self.ledger.issue(asg.actor, version, hash, now, asg.requests as usize);
            let job = GenJob {
                step,
                version,
                hash,
                pids: claimed,
                rng_seed: job_seed(self.cfg.seed, step, asg.actor),
            };
            out.push((asg, job));
        }
        Ok(out)
    }

    /// Submit one assignment's results under the acceptance predicate and
    /// settle the scheduler with *per-assignment* tokens and duration (the
    /// old loop credited cumulative totals across actors, corrupting tau).
    /// `result_hash` is the checkpoint hash attached to the results — the
    /// hub's own lease hash for the in-process sequential executor, the
    /// actor-echoed hash over a transport (the §5.4 predicate end-to-end).
    /// Returns with `rollouts` filtered down to the accepted prompts:
    /// work whose lease lapsed mid-flight (`LeaseExpired`) or already
    /// migrated to a survivor (`UnknownLease` after a failover sweep
    /// re-pooled it) is dropped instead of killing the run.
    fn submit_and_settle(
        &mut self,
        actor: u32,
        job: &GenJob,
        result_hash: [u8; 32],
        rollouts: &mut Vec<Rollout>,
        tokens: u64,
        elapsed_s: f64,
    ) -> Result<()> {
        let now = self.lease_now();
        let mut dropped: Vec<u64> = Vec::new();
        for &pid in &job.pids {
            match self.ledger.submit(actor, pid, job.version, result_hash, now) {
                Ok(()) => {}
                Err(Reject::LeaseExpired) | Err(Reject::UnknownLease) => dropped.push(pid),
                Err(e) => bail!("ledger rejected {pid}: {e:?}"),
            }
        }
        if !dropped.is_empty() {
            rollouts.retain(|r| !dropped.contains(&r.prompt_id));
        }
        let dt = if self.cfg.deterministic {
            // Virtual duration pinned to the current estimate: tau stays at
            // its prior, so allocation is identical across executors.
            (tokens as f64 / self.sched.tau(actor).unwrap_or(1.0).max(1e-9)).max(1e-6)
        } else {
            elapsed_s.max(1e-3)
        };
        self.sched.settle(actor, tokens, dt);
        Ok(())
    }

    /// Close out a step's generation accounting.
    fn finish_generation(&mut self, step: u64, batch: &[Rollout], rollout_ms: f64) {
        let a = &mut self.accum[step as usize];
        a.mean_reward = batch.iter().map(|r| r.reward).sum::<f32>() / batch.len().max(1) as f32;
        a.gen_tokens = batch.iter().map(|r| r.generated_tokens.len() as u64).sum();
        a.rollout_ms = rollout_ms;
    }

    /// Train on `batch_step`'s rollouts, then run the fused delta
    /// extract+encode+segment pass, handing each wire-ready segment to
    /// `sink` (the staging path) mid-scan. Advances the trainer-side
    /// version; actor commits are the caller's job.
    fn train_and_stream<F: FnMut(Segment)>(
        &mut self,
        batch_step: u64,
        batch: &[Rollout],
        mut sink: F,
    ) -> Result<()> {
        let shape = self.comp.shape();
        let adv = group_advantages(batch, self.cfg.algorithm);
        let pairs: Vec<(Vec<i32>, Vec<i32>)> = batch
            .iter()
            .map(|r| (r.prompt_tokens.clone(), r.generated_tokens.clone()))
            .collect();
        let packed = pack_batch(&pairs, shape.b_train, shape.max_seq);
        let mut adv_padded = vec![0.0f32; shape.b_train];
        adv_padded[..adv.len()].copy_from_slice(&adv);

        let train_start = self.now_s();
        let t_train = Instant::now();
        let loss = self.comp.train_step(
            &mut self.state,
            &packed.tokens,
            &packed.gen_mask,
            &adv_padded,
            self.cfg.lr_rl,
        )?;
        let train_ms = t_train.elapsed().as_secs_f64() * 1e3;
        let train_end = self.now_s();
        self.timeline.record("trainer", SpanKind::Train, train_start, train_end, batch_step);

        let extract_start = self.now_s();
        let t_extract = Instant::now();
        let new_policy = self.state.to_policy();
        let t0c = self.t0;
        let mut first_seg: Option<f64> = None;
        let mut last_seg = extract_start;
        let mut n_segs: u64 = 0;
        let (ckpt, stats) = stream_checkpoint(
            self.layout,
            &self.policy,
            &new_policy,
            self.version,
            self.version + 1,
            self.cfg.segment_bytes,
            |seg| {
                let now = t0c.elapsed().as_secs_f64();
                first_seg.get_or_insert(now);
                last_seg = now;
                n_segs += 1;
                sink(seg);
            },
        );
        let extract_ms = t_extract.elapsed().as_secs_f64() * 1e3;
        self.timeline.record("trainer", SpanKind::Extract, extract_start, self.now_s(), batch_step);
        if let Some(f) = first_seg {
            self.timeline.record("transfer", SpanKind::Transfer, f, last_seg, batch_step);
        }

        let rho = stats.nnz as f64 / self.layout.total_params() as f64;
        let payload = ckpt.payload_bytes();
        let hash = ckpt.hash;
        // Durability step 1–3 (objects + manifest): the delta artifact
        // and the full-precision optimizer state must be on disk before
        // anything in memory observes the new version. The journal
        // record below — step 4, the actual commit point — only lands
        // after the policy books close.
        if let Some(d) = self.durable.as_mut() {
            d.seal_version(&ckpt, &self.state)
                .map_err(|e| anyhow!("sealing v{} durably: {e}", ckpt.version))?;
        }
        self.store.put(ckpt)?;
        self.version += 1;
        self.version_hash = hash;
        self.policy = new_policy;

        {
            let a = &mut self.accum[batch_step as usize];
            a.loss = loss;
            a.train_ms = train_ms;
            a.extract_ms = extract_ms;
            a.rho = rho;
            a.payload_bytes = payload;
        }
        self.accum[batch_step as usize].policy_checksum = policy_checksum(&self.policy);
        // Durability step 4: journal the commit. Version, trained step,
        // SHA-256 policy witness, task counter, and the per-(step, actor)
        // generation seeds — everything resume needs to continue the
        // committed-checksum trace bit-exactly. Written strictly after
        // the objects above are durable: a crash between seal and journal
        // leaves an invisible (recommittable) version, never a phantom.
        if self.durable.is_some() {
            let actors: BTreeSet<u32> = batch.iter().map(|r| r.actor).collect();
            let seeds: Vec<SeedRecord> = actors
                .into_iter()
                .map(|a| SeedRecord { actor: a, seed: job_seed(self.cfg.seed, batch_step, a) })
                .collect();
            let witness = self.accum[batch_step as usize].policy_checksum;
            let (version, task_counter) = (self.version, self.task_counter);
            self.durable
                .as_mut()
                .expect("checked above")
                .append_commit(version, batch_step, witness, task_counter, seeds)
                .map_err(|e| anyhow!("journaling v{version}: {e}"))?;
        }
        // The step's books are closed: generation landed during this
        // loop iteration's overlap window, training/extraction just
        // finished. Emit the observation events the report is later
        // assembled from.
        let log = self.step_log(batch_step);
        self.emit(SessionEvent::DeltaStreamed {
            version: self.version,
            payload_bytes: payload,
            stripes: n_segs,
        });
        self.emit(SessionEvent::Committed {
            version: self.version,
            checksum: log.policy_checksum,
        });
        self.emit(SessionEvent::StepCompleted(log));
        if self.cfg.verbose {
            println!("{}", log.progress_line());
        }
        Ok(())
    }

    /// The per-step record for `step` as currently accumulated.
    fn step_log(&self, step: u64) -> StepLog {
        let a = &self.accum[step as usize];
        StepLog {
            step,
            loss: a.loss,
            mean_reward: a.mean_reward,
            rho: a.rho,
            payload_bytes: a.payload_bytes,
            dense_bytes: self.layout.dense_bytes_bf16(),
            gen_tokens: a.gen_tokens,
            extract_ms: a.extract_ms,
            train_ms: a.train_ms,
            rollout_ms: a.rollout_ms,
            policy_checksum: a.policy_checksum,
        }
    }

    /// First-run durability: persist the base (v0) snapshot, optimizer
    /// state, and genesis journal record before any RL step mutates
    /// them. A no-op for in-memory runs and for resumed stores, which
    /// already hold their genesis.
    fn write_genesis(&mut self) -> Result<()> {
        let (layout, task_counter, seed) = (self.layout, self.task_counter, self.cfg.seed);
        if let Some(d) = self.durable.as_mut() {
            if d.is_fresh() {
                d.put_genesis(layout, &self.policy, &self.state, task_counter, seed)
                    .map_err(|e| anyhow!("writing durable genesis: {e}"))?;
            }
        }
        Ok(())
    }

    /// Rebuild the generation batch that was in flight when the run
    /// died. Under the one-step-off schedule, batch `V` is generated on
    /// policy `v_{V-1}` concurrently with the training that commits
    /// `v_V`; the journal's last record proves `v_V` committed, so batch
    /// `V` existed only in memory and is lost. Replaying the *same*
    /// leases (prompt ids re-derived from the genesis counter) with the
    /// *same* per-(step, actor) seeds against the *same* `v_{V-1}`
    /// policy reproduces it bit-exactly — the deterministic schedule
    /// pins tau at its prior, so allocation matches the original run.
    fn regenerate_pending(&mut self, prev_policy: ParamSet, prev_hash: [u8; 32]) -> Result<()> {
        let v = self.version;
        let prev_v = v - 1;
        // The scheduler registered everyone at v0; the original run had
        // observed them at `v_{V-1}` when batch V was planned.
        for i in 0..self.cfg.n_actors {
            self.sched
                .observe_version(i as u32, VersionState { active: prev_v, staged: None });
        }
        // Batch V's prompt ids are fully determined by the genesis
        // counter: batches 0..V each consumed one step's worth. Deriving
        // from genesis (rather than rewinding the last journaled value)
        // handles both shapes the journal can be in at version V — a
        // mid-run crash, where batch V's prompts were already posted,
        // and a cleanly finished shorter run being extended, where the
        // epilogue committed v_V without ever planning batch V.
        let genesis_tc = match self.durable.as_ref().and_then(|d| d.records().first()) {
            Some(JournalRecord::Genesis { task_counter, .. }) => *task_counter,
            _ => bail!("resume without a durable genesis record"),
        };
        self.task_counter = genesis_tc + v * self.prompts_per_step as u64;
        let jobs = self.plan_step_at(v, prev_v, prev_hash)?;
        let phase_t = Instant::now();
        let mut scratch = PolicyState::new(self.layout.clone(), prev_policy.clone(), prev_v);
        let mut batch: Vec<Rollout> = Vec::new();
        for (asg, job) in &jobs {
            let t_job = Instant::now();
            let (mut rollouts, tokens) = run_gen_job(
                self.comp,
                self.cfg,
                &mut scratch,
                &prev_policy,
                asg.actor,
                job,
                |_| Ok(()),
            )
            .map_err(anyhow::Error::msg)?;
            let elapsed = t_job.elapsed().as_secs_f64();
            self.submit_and_settle(asg.actor, job, job.hash, &mut rollouts, tokens, elapsed)?;
            batch.extend(rollouts);
        }
        // Workers start at the resumed version V, not V-1.
        for i in 0..self.cfg.n_actors {
            self.sched.observe_version(i as u32, VersionState { active: v, staged: None });
        }
        self.finish_generation(v, &batch, phase_t.elapsed().as_secs_f64() * 1e3);
        self.resume_pending = Some((v, batch));
        self.start_step = v + 1;
        Ok(())
    }
}

/// Run the full loop (SFT warmup + RL) on any [`Compute`] backend,
/// blocking the calling thread. Legacy entry point: internally this is
/// one `run_observed` pass whose events are folded straight into the
/// report by the same assembler `Session::join` uses, so the blocking
/// API and the streaming API can never report different runs.
/// New code should prefer [`crate::session::Session`].
pub fn run_with_compute<C: Compute>(
    cfg: &LocalRunConfig,
    layout: &ModelLayout,
    comp: &C,
    mode: ExecMode,
) -> Result<RunReport> {
    let mut asm = ReportAssembler::default();
    let never = AtomicBool::new(false);
    let mut sink = |ev: SessionEvent| asm.record(&ev);
    let tail = run_observed(cfg, layout, comp, mode, &mut sink, &never)?;
    Ok(asm.finish(tail))
}

/// Run the full loop (SFT warmup + RL) on any [`Compute`] backend with a
/// typed event sink and a cooperative cancellation flag — the engine
/// under both [`run_with_compute`] and the Session API. `layout` must
/// match the backend's parameter geometry. Every event is emitted from
/// the calling (hub) thread; setting `cancel` makes the run bail with
/// [`crate::session::ABORT_MSG`] at its next cancellation point.
pub(crate) fn run_observed<'a, C: Compute>(
    cfg: &'a LocalRunConfig,
    layout: &'a ModelLayout,
    comp: &'a C,
    mode: ExecMode,
    sink: &'a mut (dyn FnMut(SessionEvent) + 'a),
    cancel: &'a AtomicBool,
) -> Result<RunTail> {
    let wall0 = Instant::now();
    let shape = comp.shape();
    if cfg.group_size == 0 || cfg.group_size > shape.b_gen {
        bail!("group_size {} must be in 1..={}", cfg.group_size, shape.b_gen);
    }
    if cfg.group_size > shape.b_train {
        bail!("group_size {} exceeds b_train {}", cfg.group_size, shape.b_train);
    }
    if cfg.n_actors == 0 {
        bail!("need at least one actor");
    }
    if let Some(spec) = &cfg.distribution {
        if !spec.is_flat() && spec.region_of.len() != cfg.n_actors {
            bail!(
                "distribution spec covers {} actors but n_actors is {}",
                spec.region_of.len(),
                cfg.n_actors
            );
        }
    }
    // ---------------- Durable store / resume ----------------------------
    let mut durable: Option<DurableStore> = None;
    let mut resume_from: Option<ResumePoint> = None;
    if let Some(dir) = &cfg.persist_dir {
        let store = DurableStore::open(dir)
            .map_err(|e| anyhow!("durable store at {}: {e}", dir.display()))?;
        if cfg.resume {
            ensure!(
                cfg.deterministic && !cfg.wall_leases,
                "resume requires the deterministic schedule (wall-clock leases would \
                 make the replayed in-flight batch diverge)"
            );
            ensure!(
                cfg.elastic.joins.is_empty() && cfg.elastic.leaves.is_empty(),
                "resume cannot be combined with scripted elastic membership"
            );
            ensure!(!store.is_fresh(), "nothing to resume: {} holds no durable run", dir.display());
            let rp = store
                .resume_point(layout, cfg.seed)
                .map_err(|e| anyhow!("recovering durable run at {}: {e}", dir.display()))?;
            ensure!(
                rp.version <= cfg.steps,
                "durable run is already at v{} but the spec asks for only {} steps",
                rp.version,
                cfg.steps
            );
            resume_from = Some(rp);
        } else {
            ensure!(
                store.is_fresh(),
                "{} already holds a durable run; resume it or point at an empty directory",
                dir.display()
            );
        }
        durable = Some(store);
    } else {
        ensure!(!cfg.resume, "resume needs a persist_dir to recover from");
    }

    // ---------------- RL phase ------------------------------------------
    let mut hub = match resume_from {
        Some(rp) => {
            // Resumed run: SFT and steps `0..V` are already folded into
            // the persisted optimizer state. Rebuild the hub at the last
            // durable version, reseed the in-memory chain (elastic
            // bootstraps replay from it), and regenerate the one
            // in-flight batch the crash lost.
            let ResumePoint {
                version,
                state,
                policy: _,
                version_hash,
                task_counter,
                prev_policy,
                prev_hash,
                chain,
            } = rp;
            let mut hub = Hub::new(cfg, layout, comp, state, task_counter, durable, sink, cancel);
            hub.version = version;
            hub.version_hash = version_hash;
            for ckpt in chain {
                hub.store.put(ckpt)?;
            }
            if version >= 1 && version < cfg.steps {
                let prev =
                    prev_policy.expect("resume_point retains the pre-crash policy for v >= 1");
                hub.regenerate_pending(prev, prev_hash)?;
            } else {
                // v0 (crash before the first commit) restarts the loop
                // from the top; v == steps has nothing left to run.
                hub.start_step = version;
            }
            hub
        }
        None => {
            let mut rng = Rng::new(cfg.seed);
            let mut state = TrainState::init(layout, &mut rng);

            // ------------ SFT warmup: same train path, adv = 1 ----------
            let mut task_counter: u64 = 0;
            for step in 0..cfg.sft_steps {
                if cancel.load(Ordering::Relaxed) {
                    bail!("{}", crate::session::ABORT_MSG);
                }
                let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..shape.b_train)
                    .map(|_| {
                        task_counter += 1;
                        let task = Task::from_prompt_id(task_counter, cfg.bench);
                        (task.prompt_tokens(), task.answer_tokens())
                    })
                    .collect();
                let batch = pack_batch(&pairs, shape.b_train, shape.max_seq);
                let adv = vec![1.0f32; shape.b_train];
                let loss =
                    comp.train_step(&mut state, &batch.tokens, &batch.gen_mask, &adv, cfg.lr_sft)?;
                sink(SessionEvent::SftStep { step, loss });
            }
            let mut hub = Hub::new(cfg, layout, comp, state, task_counter, durable, sink, cancel);
            // Base snapshot + genesis record before the first RL step.
            hub.write_genesis()?;
            hub
        }
    };
    match mode {
        ExecMode::Sequential => run_sequential(&mut hub)?,
        ExecMode::Pipelined => run_pipelined(&mut hub)?,
    }
    Ok(RunTail {
        final_version: hub.version,
        wall_s: wall0.elapsed().as_secs_f64(),
        timeline: hub.timeline,
    })
}

/// Stream `D_{v}` into in-process actors and commit at their safe points
/// (the sequential executor's staging+commit tail for one version).
fn seq_stream_and_commit<C: Compute>(
    hub: &mut Hub<C>,
    actors: &mut [PolicyState],
    batch_step: u64,
    batch: &[Rollout],
) -> Result<()> {
    let mut stream_err: Option<String> = None;
    let last = actors.len() - 1;
    hub.train_and_stream(batch_step, batch, |seg| {
        for (i, actor) in actors[..last].iter_mut().enumerate() {
            if let Err(e) = actor.on_segment(seg.clone()) {
                stream_err.get_or_insert(format!("actor {i} staging: {e}"));
            }
        }
        if let Err(e) = actors[last].on_segment(seg) {
            stream_err.get_or_insert(format!("actor {last} staging: {e}"));
        }
    })?;
    if let Some(e) = stream_err {
        bail!("{e}");
    }
    let v = hub.version;
    for (i, actor) in actors.iter_mut().enumerate() {
        hub.sched.note_staged(i as u32, v);
        let c0 = hub.t0.elapsed().as_secs_f64();
        match actor.request_commit(v) {
            CommitResult::Applied => {}
            other => bail!("actor {i} commit failed: {other:?}"),
        }
        let c1 = hub.t0.elapsed().as_secs_f64();
        hub.timeline.record(&format!("actor{i}"), SpanKind::Commit, c0, c1, batch_step);
        // Bit-exactness: every actor's policy equals the trainer's.
        if actor.params() != &hub.policy {
            bail!("actor {i} diverged from trainer policy at v{v}");
        }
        hub.sched.note_committed(i as u32, v);
    }
    Ok(())
}

/// One scripted hot-swap, composed and sealed for the wire: the actor it
/// targets, the published fine-tune it lands on (registry numbering),
/// the renumbered checkpoint the staging machinery applies, and the
/// registry witness the swapped actor must echo. Holds the GC pin for
/// every registry object the composition read — dropped only after the
/// swap is acknowledged, so a concurrent `registry gc` cannot collect a
/// version the composition still depends on.
struct PreparedSwap {
    actor: u32,
    model: String,
    /// Target version in *registry* numbering (what `Event::Swapped` and
    /// the witness check use; the wire rides `ckpt.version`).
    version: u64,
    ckpt: DeltaCheckpoint,
    witness: [u8; 32],
    _pin: SwapPin,
}

/// Registry-publish epilogue, shared by both executors. Runs after the
/// final training commit and *before* any scripted swap, so a run that
/// publishes itself and immediately swaps away (A/B rotation on one
/// fleet) finds its own chain in the registry. Folds the durable chain
/// into one compacted delta off the shared base and records it under
/// `cfg.publish`; content addressing makes a bit-identical republish (or
/// a second fine-tune off the same base) dedup to existing objects.
fn run_registry_publish<C: Compute>(hub: &Hub<C>) -> Result<()> {
    let Some(name) = &hub.cfg.publish else { return Ok(()) };
    let reg_dir = hub
        .cfg
        .registry_dir
        .as_ref()
        .ok_or_else(|| anyhow!("publish needs a registry dir (RunSpec::publish_to)"))?;
    let store = hub
        .durable
        .as_ref()
        .ok_or_else(|| anyhow!("publish needs a durable run (RunSpec::persist)"))?;
    let mut reg = ModelRegistry::open(reg_dir)
        .map_err(|e| anyhow!("model registry at {}: {e}", reg_dir.display()))?;
    reg.publish(store, hub.layout, name, None)
        .map_err(|e| anyhow!("publishing run as model {name:?}: {e}"))?;
    Ok(())
}

/// Compose every scripted swap against the registry. The source is
/// located by the hub's *final* policy witness — the run must have been
/// published (this run via `publish`, or an earlier bit-identical run),
/// otherwise there is no chain to invert and we fail with the witness in
/// hand. Each composed delta `compose(invert(chain_src), chain_tgt)` is
/// renumbered onto the live version line (`base_version = V`,
/// `version = V+1`) and re-sealed, so actors apply it through the
/// ordinary `Seg`/`Commit` staging path with no new code on their side.
fn prepare_swaps<C: Compute>(hub: &Hub<C>) -> Result<Vec<PreparedSwap>> {
    if hub.cfg.swaps.is_empty() {
        return Ok(Vec::new());
    }
    let reg_dir = hub
        .cfg
        .registry_dir
        .as_ref()
        .ok_or_else(|| anyhow!("scripted swaps need a registry dir (RunSpec::registry)"))?;
    let reg = ModelRegistry::open(reg_dir)
        .map_err(|e| anyhow!("model registry at {}: {e}", reg_dir.display()))?;
    let here = policy_checksum(&hub.policy);
    let (src_model, src_version) = reg.locate(&here).ok_or_else(|| {
        anyhow!(
            "hot-swap: the run's final policy (witness {}) matches no published model \
             version in {}; publish this configuration first",
            crate::util::hex(&here),
            reg_dir.display()
        )
    })?;
    let wire_v = hub.version + 1;
    let mut out = Vec::with_capacity(hub.cfg.swaps.len());
    for spec in &hub.cfg.swaps {
        let witness = reg
            .witness(&spec.model, spec.version)
            .map_err(|e| anyhow!("hot-swap target {}@v{}: {e}", spec.model, spec.version))?;
        let pin = reg
            .pin_swap((&src_model, src_version), (&spec.model, spec.version))
            .map_err(|e| anyhow!("pinning swap objects: {e}"))?;
        let mut delta = reg
            .compose_swap(hub.layout, (&src_model, src_version), (&spec.model, spec.version))
            .map_err(|e| {
                anyhow!(
                    "composing swap {}@v{} -> {}@v{}: {e}",
                    src_model,
                    src_version,
                    spec.model,
                    spec.version
                )
            })?;
        // Registry numbering (src_version -> spec.version) becomes live
        // numbering: the actor sits at V, the swap commits as V+1.
        delta.base_version = hub.version;
        delta.version = wire_v;
        out.push(PreparedSwap {
            actor: spec.actor,
            model: spec.model.clone(),
            version: spec.version,
            ckpt: DeltaCheckpoint::seal(&delta),
            witness,
            _pin: pin,
        });
    }
    Ok(out)
}

/// Sequential executor's swap epilogue: stage + commit each composed
/// swap delta directly on the in-process actor and verify the swapped
/// policy against the registry witness before announcing it.
fn run_swap_script_sequential<C: Compute>(
    hub: &mut Hub<C>,
    actors: &mut [PolicyState],
) -> Result<()> {
    for swap in prepare_swaps(hub)? {
        let a = swap.actor as usize;
        let wire_v = swap.ckpt.version;
        for seg in split_into_segments(wire_v, &swap.ckpt.bytes, hub.cfg.segment_bytes) {
            actors[a]
                .on_segment(seg)
                .map_err(|e| anyhow!("actor {a} swap staging: {e}"))?;
        }
        match actors[a].request_commit(wire_v) {
            CommitResult::Applied => {}
            other => bail!("actor {a} swap commit failed: {other:?}"),
        }
        if policy_checksum(actors[a].params()) != swap.witness {
            bail!(
                "actor {a} swap to {}@v{} diverged from the registry witness",
                swap.model,
                swap.version
            );
        }
        hub.emit(SessionEvent::Swapped {
            actor: swap.actor,
            model: swap.model,
            version: swap.version,
            bytes: swap.ckpt.payload_bytes(),
        });
    }
    Ok(())
}

/// Phase-sequential executor over the shared one-step-off schedule.
fn run_sequential<C: Compute>(hub: &mut Hub<C>) -> Result<()> {
    // Fresh runs start every actor at v0; a resumed run starts them at
    // the recovered version, seeded with the recovered policy.
    let mut actors: Vec<PolicyState> = (0..hub.cfg.n_actors)
        .map(|_| {
            PolicyState::new(hub.layout.clone(), hub.policy.clone(), hub.version)
                .with_active_hash(hub.version_hash)
        })
        .collect();
    let mut pending: Option<(u64, Vec<Rollout>)> = hub.resume_pending.take();
    for step in hub.start_step..hub.cfg.steps {
        hub.check_cancel()?;
        let jobs = hub.plan_step(step)?;
        let phase_t = Instant::now();
        let mut batch: Vec<Rollout> = Vec::new();
        for (asg, job) in &jobs {
            let a = asg.actor as usize;
            let start_s = hub.now_s();
            let t_job = Instant::now();
            let (policy, _hash) = actors[a]
                .behaviour_policy(job.version)
                .ok_or_else(|| anyhow!("actor {a} has no behaviour policy for v{}", job.version))?;
            let (mut rollouts, tokens) =
                run_gen_job(hub.comp, hub.cfg, &mut actors[a], &policy, asg.actor, job, |_| Ok(()))
                    .map_err(anyhow::Error::msg)?;
            let elapsed = t_job.elapsed().as_secs_f64();
            let end_s = hub.now_s();
            hub.timeline.record(&format!("actor{a}"), SpanKind::Rollout, start_s, end_s, step);
            hub.submit_and_settle(asg.actor, job, job.hash, &mut rollouts, tokens, elapsed)?;
            batch.extend(rollouts);
        }
        hub.finish_generation(step, &batch, phase_t.elapsed().as_secs_f64() * 1e3);
        // Train on the previous batch — after this step's generation, the
        // same dependency order the pipelined executor overlaps.
        if let Some((prev_step, prev)) = pending.take() {
            seq_stream_and_commit(hub, &mut actors, prev_step, &prev)?;
        }
        pending = Some((step, batch));
    }
    if let Some((prev_step, prev)) = pending.take() {
        seq_stream_and_commit(hub, &mut actors, prev_step, &prev)?;
    }
    run_registry_publish(hub)?;
    run_swap_script_sequential(hub, &mut actors)?;
    Ok(())
}

/// Reconstruct a worker-side job from its wire form. The lease hash
/// lives hub-side only — the worker echoes the checkpoint hash its
/// [`PolicyState::behaviour_policy`] resolves for the job's version — and
/// `step` is folded into `version` (the hub never reads it back; slots
/// are keyed by prompt id).
fn wire_job(version: u64, rng_seed: u64, pids: Vec<u64>) -> GenJob {
    GenJob { step: version, version, hash: [0u8; 32], pids, rng_seed }
}

/// Drain the endpoint without blocking, then let any parked commit land
/// if we are at a safe point. Segments stage regardless of the
/// generating flag; a `Commit` delivered mid-batch parks via
/// [`PolicyState::request_commit`] and is applied (and acknowledged) by
/// the trailing [`PolicyState::on_safe_point`] once `generating` drops.
/// `Job` messages are parked on the backlog for the main loop. A closed
/// endpoint mid-drain is not an error: the batch finishes and the main
/// loop observes the shutdown.
fn worker_drain(
    ep: &mut dyn ActorEndpoint,
    state: &mut PolicyState,
    backlog: &mut VecDeque<GenJob>,
    actor: u32,
) -> Result<(), String> {
    loop {
        match ep.try_recv() {
            Ok(Some(Msg::Seg(seg))) => {
                state
                    .on_segment(seg)
                    .map_err(|e| format!("actor {actor} staging: {e}"))?;
            }
            Ok(Some(Msg::Commit { version })) => {
                commit_and_ack(state, actor, version, ep)?;
            }
            Ok(Some(Msg::Job { version, rng_seed, prompt_ids })) => {
                backlog.push_back(wire_job(version, rng_seed, prompt_ids));
            }
            // A mid-batch Bye only happens while the hub is tearing down;
            // the disconnect surfaces at the next blocking recv. The hub
            // grants Drain only to an idle actor, so one cannot arrive
            // mid-batch; tolerate it the same way. Swap is a pure
            // annotation — its delta rides the Seg/Commit arms above.
            Ok(Some(Msg::Bye)) | Ok(Some(Msg::Drain { .. })) | Ok(Some(Msg::Swap { .. })) => {}
            Ok(Some(other)) => return Err(format!("actor {actor}: unexpected {other:?}")),
            Ok(None) | Err(Closed) => break,
        }
    }
    service_safe_point(state, actor, ep)
}

/// Deliver `Commit(v)`: apply immediately at a safe point, or park it
/// mid-generation-batch (`Deferred`) — the ack then rides the apply in
/// [`service_safe_point`]. Never applies under `generating == true`.
fn commit_and_ack(
    state: &mut PolicyState,
    actor: u32,
    version: u64,
    ep: &mut dyn ActorEndpoint,
) -> Result<(), String> {
    match state.request_commit(version) {
        CommitResult::Applied => ack_commit(state, actor, version, ep),
        CommitResult::Deferred => Ok(()),
        other => Err(format!("actor {actor} commit v{version} failed: {other:?}")),
    }
}

/// Apply (and acknowledge) any commit parked while a batch was generating.
/// No-op when nothing is pending or we are not at a safe point.
fn service_safe_point(
    state: &mut PolicyState,
    actor: u32,
    ep: &mut dyn ActorEndpoint,
) -> Result<(), String> {
    match state.on_safe_point() {
        None => Ok(()),
        Some((v, CommitResult::Applied)) => ack_commit(state, actor, v, ep),
        Some((v, other)) => Err(format!("actor {actor} deferred commit v{v} failed: {other:?}")),
    }
}

/// Send the `Activated` acknowledgement carrying the bit-exactness
/// witness (SHA-256 of the post-commit policy).
fn ack_commit(
    state: &PolicyState,
    actor: u32,
    version: u64,
    ep: &mut dyn ActorEndpoint,
) -> Result<(), String> {
    ep.send(Msg::Activated { actor, version, hash: policy_checksum(state.params()) })
        .map_err(|_| "hub exited".to_string())
}

/// One actor worker, generic over the transport backend: owns its
/// [`PolicyState`], speaks the `Msg` protocol through its endpoint, and
/// generates rollouts while staging deltas that arrive mid-generation at
/// inter-batch safe points. The identical function runs on an in-process
/// thread (`InProc`/`Sim`) and behind loopback sockets (`Tcp`); errors
/// become transport `Down` events at the hub, which fails the actor over
/// instead of aborting the run.
fn actor_worker<C: Compute>(
    comp: &C,
    cfg: &LocalRunConfig,
    actor: u32,
    state: PolicyState,
    ep: &mut dyn ActorEndpoint,
) -> Result<(), String> {
    // Membership: introduce ourselves before any work flows.
    if ep.send(Msg::Hello { actor, prior_tau: 1000.0 }).is_err() {
        return Ok(()); // hub gone before the run started
    }
    actor_loop(comp, cfg, actor, state, ep)
}

/// A scripted late joiner (elastic membership): launched dormant — no
/// Hello, invisible to the membership barrier and excluded from the
/// broadcast fan-out — until the hub's `Invite` models the provisioner
/// granting capacity. It then announces itself (`Join` with capability
/// and region info), bootstraps to the active version — a dense
/// `Snapshot`, or the stored delta chain `D_1..D_v` replayed through the
/// *same* staging decoders and chained commit the steady-state stream
/// uses — acks the bit-exactness witness, and runs the normal worker
/// loop.
fn joiner_worker<C: Compute>(
    comp: &C,
    cfg: &LocalRunConfig,
    actor: u32,
    mut state: PolicyState,
    ep: &mut dyn ActorEndpoint,
) -> Result<(), String> {
    // Dormant phase: wait to be provisioned.
    loop {
        match ep.recv() {
            Ok(Msg::Invite { actor: a }) => {
                if a != actor {
                    return Err(format!("actor {actor}: invite addressed to actor {a}"));
                }
                break;
            }
            Ok(Msg::Bye) | Err(Closed) => return Ok(()), // run ended before we joined
            Ok(other) => return Err(format!("dormant actor {actor}: unexpected {other:?}")),
        }
    }
    // Announce ourselves over the transport.
    if ep.send(Msg::Join { actor, prior_tau: 1000.0, region: 0 }).is_err() {
        return Ok(()); // hub gone mid-join
    }
    // Bootstrap phase: runs until the commit (or snapshot) for the
    // hub-announced target version applies. Chain segments may ride
    // striped/reordered paths, so a Commit can overtake them — the
    // standard park-then-safe-point machinery absorbs that here too.
    let mut target: Option<u64> = None;
    while target.map_or(true, |t| state.active_version() < t) {
        match ep.recv() {
            Ok(Msg::Seg(seg)) => {
                state
                    .on_segment(seg)
                    .map_err(|e| format!("actor {actor} bootstrap staging: {e}"))?;
                service_safe_point(&mut state, actor, ep)?;
            }
            Ok(Msg::Commit { version }) => {
                target = Some(version);
                commit_and_ack(&mut state, actor, version, ep)?;
            }
            Ok(Msg::Snapshot { version, hash, data }) => {
                state
                    .install_snapshot(version, hash, &data)
                    .map_err(|e| format!("actor {actor} snapshot bootstrap: {e}"))?;
                target = Some(version);
                // The witness ack doubles as the admission request.
                ack_commit(&state, actor, version, ep)?;
            }
            Ok(Msg::Bye) | Err(Closed) => return Ok(()), // run ended mid-bootstrap
            Ok(other) => return Err(format!("joining actor {actor}: unexpected {other:?}")),
        }
    }
    // Admitted: steady state from here on.
    actor_loop(comp, cfg, actor, state, ep)
}

/// The steady-state worker loop shared by day-one actors (after their
/// Hello) and admitted joiners (after bootstrap).
fn actor_loop<C: Compute>(
    comp: &C,
    cfg: &LocalRunConfig,
    actor: u32,
    mut state: PolicyState,
    ep: &mut dyn ActorEndpoint,
) -> Result<(), String> {
    let mut backlog: VecDeque<GenJob> = VecDeque::new();
    loop {
        let job = match backlog.pop_front() {
            Some(job) => Some(job),
            None => match ep.recv() {
                Ok(Msg::Job { version, rng_seed, prompt_ids }) => {
                    Some(wire_job(version, rng_seed, prompt_ids))
                }
                Ok(Msg::Seg(seg)) => {
                    state
                        .on_segment(seg)
                        .map_err(|e| format!("actor {actor} staging: {e}"))?;
                    // A commit that overtook these segments (striped
                    // sockets and relay routing reorder hub→actor paths)
                    // lands as soon as staging completes.
                    service_safe_point(&mut state, actor, ep)?;
                    None
                }
                Ok(Msg::Commit { version }) => {
                    commit_and_ack(&mut state, actor, version, ep)?;
                    None
                }
                Ok(Msg::Swap { .. }) => {
                    // Hot-swap annotation: the composed swap delta itself
                    // arrives as ordinary Seg/Commit traffic right behind
                    // this marker — nothing to do here; the staging
                    // machinery retargets us exactly as a training commit
                    // would.
                    None
                }
                Ok(Msg::Drain { .. }) => {
                    // Graceful release: the hub settled our books and is
                    // letting us go. Confirm with Bye — a clean EOF on
                    // every transport, so no Down event, no failover.
                    let _ = ep.send(Msg::Bye);
                    return Ok(());
                }
                Ok(Msg::Bye) | Err(Closed) => return Ok(()), // orderly shutdown
                Ok(other) => return Err(format!("actor {actor}: unexpected {other:?}")),
            },
        };
        let Some(job) = job else { continue };
        // Resolve the behaviour snapshot + checkpoint hash for the job's
        // version NOW: a commit landing at a mid-job safe point advances
        // `state`, but the lease (and the §5.4 predicate) bind results to
        // the version the job was issued on. A re-issued failover job may
        // even start on a version this actor already replaced — served
        // from the retained sparse inverse.
        let Some((policy, hash)) = state.behaviour_policy(job.version) else {
            return Err(format!(
                "actor {actor}: no behaviour policy for v{} (active v{})",
                job.version,
                state.active_version()
            ));
        };
        let (rollouts, _gen_tokens) =
            run_gen_job(comp, cfg, &mut state, &policy, actor, &job, |st| {
                worker_drain(ep, st, &mut backlog, actor)
            })?;
        drop(policy);
        // Per-rollout results, in generation order (per-actor FIFO makes
        // hub-side reassembly deterministic).
        for r in rollouts {
            let sent = ep.send(Msg::RolloutResult {
                actor,
                prompt_id: r.prompt_id,
                version: r.version,
                hash,
                reward: r.reward,
                tokens: r.generated_tokens,
            });
            if sent.is_err() {
                return Ok(()); // hub gone mid-reply
            }
        }
    }
}

/// Build the configured transport backend for a pipelined run.
fn build_transport(cfg: &LocalRunConfig) -> Result<Box<dyn Transport>> {
    Ok(match &cfg.transport {
        TransportKind::InProc => Box::new(InProcTransport::new(cfg.distribution.clone())),
        TransportKind::Sim(net) => {
            ensure!(
                net.region_of.len() == cfg.n_actors,
                "sim transport topology covers {} actors but n_actors is {}",
                net.region_of.len(),
                cfg.n_actors
            );
            Box::new(SimTransport::new(net.clone()))
        }
        TransportKind::Tcp(tc) => {
            ensure!(
                cfg.distribution.as_ref().map_or(true, |d| d.is_flat()),
                "tcp transport streams hub→actor directly; use --transport sim for WAN relay trees"
            );
            Box::new(TcpTransport::new(tc.clone()))
        }
    })
}

/// Pipelined executor: launch the configured transport backend around
/// the backend-agnostic [`actor_worker`], then per step dispatch
/// generation, train + stream the previous version concurrently, and
/// collect generation results and activation acknowledgements — failing
/// over to survivors when a transport `Down` event or a lease expiry
/// reports a lost actor.
fn run_pipelined<C: Compute>(hub: &mut Hub<C>) -> Result<()> {
    let n = hub.cfg.n_actors;
    let comp = hub.comp;
    let cfg = hub.cfg;
    let elastic = &cfg.elastic;
    let n_total = n + elastic.joins.len();
    if !elastic.joins.is_empty() || !elastic.leaves.is_empty() {
        ensure!(
            !matches!(cfg.transport, TransportKind::Sim(_)),
            "elastic membership needs --transport inproc or tcp (netsim fleets are fixed)"
        );
        ensure!(
            cfg.distribution.as_ref().map_or(true, |d| d.is_flat()),
            "elastic membership requires flat hub→actor streaming (no relay trees)"
        );
        let mut ids: Vec<u32> = elastic.joins.iter().map(|j| j.actor).collect();
        ids.sort_unstable();
        ids.dedup();
        ensure!(
            ids.len() == elastic.joins.len()
                && ids == (n as u32..n_total as u32).collect::<Vec<u32>>(),
            "scripted joiners must be actors {n}..{n_total} exactly (one id each)"
        );
        for j in &elastic.joins {
            ensure!(
                (1..=cfg.steps).contains(&j.at_version),
                "join for actor {} at v{} outside 1..={}",
                j.actor,
                j.at_version,
                cfg.steps
            );
        }
        for l in &elastic.leaves {
            ensure!((l.actor as usize) < n_total, "scripted leave names unknown actor {}", l.actor);
            ensure!(
                (1..=cfg.steps).contains(&l.at_version),
                "leave for actor {} at v{} outside 1..={}",
                l.actor,
                l.at_version,
                cfg.steps
            );
        }
    }
    let layout = hub.layout.clone();
    let policy0 = hub.policy.clone();
    // Day-one workers start where the hub is: v0 for fresh runs, the
    // recovered version on resume (the active hash seeds the ledger's
    // acceptance predicate). Joiners always bootstrap from scratch —
    // resume forbids elastic scripts, so `v0 == 0` whenever they exist.
    let v0 = hub.version;
    let h0 = hub.version_hash;
    let transport = build_transport(cfg)?;
    let runner = move |actor: u32, ep: &mut dyn ActorEndpoint| -> Result<(), String> {
        if (actor as usize) < n {
            let state =
                PolicyState::new(layout.clone(), policy0.clone(), v0).with_active_hash(h0);
            actor_worker(comp, cfg, actor, state, ep)
        } else {
            let state = PolicyState::new(layout.clone(), policy0.clone(), 0);
            joiner_worker(comp, cfg, actor, state, ep)
        }
    };
    std::thread::scope(|scope| {
        let mut ep = transport.launch(scope, n_total, &runner)?;
        let result = transport_hub_loop(hub, ep.as_mut());
        // Orderly teardown regardless of outcome: Bye + closed links let
        // every worker (even a stalled or still-dormant one) exit so the
        // scope can join.
        ep.shutdown();
        result
    })
}

/// Train on the previous batch, stream its delta through the transport's
/// segment fan-out (direct mailboxes, relay tree, netsim reorder, or
/// striped sockets — the backend's business), then push `Commit` to
/// every live actor. Send failures surface as `Down` events in the
/// collect loop, so they are not errors here.
fn broadcast_and_commit<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    alive: &BTreeSet<u32>,
    batch_step: u64,
    batch: &[Rollout],
) -> Result<()> {
    hub.train_and_stream(batch_step, batch, |seg| ep.broadcast_seg(seg))?;
    let v = hub.version;
    for &a in alive {
        hub.sched.note_staged(a, v);
        let _ = ep.send(a, Msg::Commit { version: v });
    }
    Ok(())
}

/// How long the hub waits for outstanding `Activated` acks (including
/// in-flight joiner bootstraps) once all generation results are in
/// before declaring the holdouts partitioned (mirrors the 60 s
/// membership-barrier deadline). Healthy acks arrive within
/// milliseconds of the trailing safe point. The collect-loop poll
/// interval itself — the granularity of lease-expiry sweeps — comes
/// from `LeasePolicy::sweep_ms` via [`Hub::poll_interval`].
const ACK_TIMEOUT: Duration = Duration::from_secs(60);

/// One assignment's in-flight generation work, hub-side. `executing`
/// starts as the original assignment and moves to a survivor on
/// failover; the job (prompt order + RNG seed) never changes, so the
/// regenerated rollouts are bit-identical to what the dead actor would
/// have produced.
struct Slot {
    job: GenJob,
    executing: u32,
    results: Vec<Rollout>,
    /// Checkpoint hash echoed by the executing actor (must agree across
    /// a slot's results; checked against the lease on submit).
    hash: Option<[u8; 32]>,
    expect: usize,
    start_s: f64,
    end_s: f64,
    done: bool,
}

/// Hub-side view of the elastic fleet. `alive` are schedulable members;
/// `draining` are members finishing their last leases before a graceful
/// release; `warned` received a spot-preemption warning (a subsequent
/// `Down` is classified `Preempted`, not `Crash`); `joining` are invited
/// actors mid-bootstrap, not yet admitted to the scheduler.
struct Membership {
    alive: BTreeSet<u32>,
    draining: BTreeSet<u32>,
    warned: BTreeSet<u32>,
    joining: BTreeMap<u32, JoinInFlight>,
}

impl Membership {
    fn new() -> Self {
        Membership {
            alive: BTreeSet::new(),
            draining: BTreeSet::new(),
            warned: BTreeSet::new(),
            joining: BTreeMap::new(),
        }
    }
}

/// One invited actor's bootstrap in flight: the version it must reach,
/// how it gets there, and the bytes spent doing so. `announced` flips
/// when its `Msg::Join` arrives (the capability announcement that
/// carries `prior_tau` and `region` for scheduler admission).
struct JoinInFlight {
    version: u64,
    bootstrap: BootstrapKind,
    bytes: u64,
    prior_tau: f64,
    region: u32,
    announced: bool,
}

/// The transport-generic pipelined hub loop: membership barrier, then
/// per step dispatch → overlapped train/stream → collect, with
/// lease-driven failover throughout.
fn transport_hub_loop<C: Compute>(hub: &mut Hub<C>, ep: &mut dyn HubEndpoint) -> Result<()> {
    let n = hub.cfg.n_actors;
    let poll = hub.poll_interval();
    // Scripted joiners launch dormant: take them out of the broadcast
    // fan-out up front so they cannot watch pre-join deltas for free —
    // delta-chain bootstrap must pay for the history it replays.
    let joiner_ids: Vec<u32> = hub.cfg.elastic.joins.iter().map(|j| j.actor).collect();
    for actor in joiner_ids {
        ep.set_active(actor, false);
    }
    // Membership barrier: every *day-one* worker says Hello before step 0
    // (over Tcp this also proves all sockets are up). Dormant joiners
    // stay silent until invited.
    let mut mem = Membership::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    while mem.alive.len() < n {
        hub.check_cancel()?;
        match ep.poll(poll) {
            Polled::Event(Event::Msg { actor, msg: Msg::Hello { .. } }) => {
                ensure!((actor as usize) < n, "hello from unknown actor {actor}");
                mem.alive.insert(actor);
            }
            Polled::Event(Event::Msg { actor, msg }) => {
                bail!("actor {actor} sent {msg:?} before Hello")
            }
            Polled::Event(Event::Down { actor, reason }) => {
                bail!("actor {actor} died during startup: {reason}")
            }
            Polled::TimedOut => {
                ensure!(Instant::now() < deadline, "actors never joined ({}/{n})", mem.alive.len())
            }
            Polled::Closed => bail!("transport closed during startup"),
        }
    }

    // A resumed run seeds the overlap window with its regenerated
    // in-flight batch: the first loop iteration trains it exactly as the
    // uninterrupted run would have.
    let mut last_batch: Option<(u64, Vec<Rollout>)> = hub.resume_pending.take();
    for step in hub.start_step..hub.cfg.steps {
        hub.check_cancel()?;
        // 1. Dispatch this step's generation on the stale policy. Every
        //    assigned actor already acked Activated(version), so per-actor
        //    control FIFO guarantees the job lands on an applied policy.
        let jobs = hub.plan_step(step)?;
        let mut slots: Vec<Slot> = Vec::with_capacity(jobs.len());
        for (asg, job) in jobs {
            let msg = Msg::Job {
                version: job.version,
                rng_seed: job.rng_seed,
                prompt_ids: job.pids.clone(),
            };
            let start_s = hub.now_s();
            let expect = job.pids.len() * hub.cfg.group_size;
            // A drained pool can leave an assignment with zero prompts;
            // such a slot is born complete and gets no dispatch.
            slots.push(Slot {
                job,
                executing: asg.actor,
                results: Vec::new(),
                hash: None,
                expect,
                start_s,
                end_s: start_s,
                done: expect == 0,
            });
            if expect > 0 {
                // A failed send means the link is already dead; the
                // matching Down event reaches the collect loop and fails
                // it over.
                let _ = ep.send(asg.actor, msg);
            }
        }
        // 2. Train on the previous batch + stream D_{v} mid-generation.
        let committing = if let Some((prev_step, prev)) = last_batch.take() {
            broadcast_and_commit(hub, ep, &mem.alive, prev_step, &prev)?;
            Some((hub.version, hub.now_s()))
        } else {
            None
        };
        // 2b. Elastic membership at the version boundary the commit just
        //     created: invite scripted joiners, start scripted drains,
        //     let the autoscaler speak. Bootstrap and drain traffic then
        //     interleaves with normal collection below.
        run_membership_script(hub, ep, &mut mem)?;
        // 3. Collect generation results + activation acks (failover on
        //    Down events and expired leases; joins and drains settle
        //    in the same loop).
        collect_step(hub, ep, &mut mem, &mut slots, committing, step)?;
        // 4. Deterministic batch assembly in assignment order.
        let mut batch: Vec<Rollout> = Vec::new();
        let mut phase = (f64::INFINITY, 0.0f64);
        for slot in &mut slots {
            phase = (phase.0.min(slot.start_s), phase.1.max(slot.end_s));
            batch.append(&mut slot.results);
        }
        hub.finish_generation(step, &batch, (phase.1 - phase.0).max(0.0) * 1e3);
        last_batch = Some((step, batch));
    }
    // Epilogue: train + commit the final version (no generation to hide
    // behind — the same tail the sequential executor pays every step).
    if let Some((prev_step, prev)) = last_batch.take() {
        broadcast_and_commit(hub, ep, &mem.alive, prev_step, &prev)?;
        run_membership_script(hub, ep, &mut mem)?;
        let committing = Some((hub.version, hub.now_s()));
        let mut slots: Vec<Slot> = Vec::new();
        collect_step(hub, ep, &mut mem, &mut slots, committing, prev_step)?;
    }
    run_registry_publish(hub)?;
    run_swap_script_pipelined(hub, ep, &mem)?;
    Ok(())
}

/// Pipelined executor's swap epilogue: ship each composed swap delta to
/// its (still-live) target actor over the transport — a `Swap`
/// annotation, then ordinary `Seg`/`Commit` traffic — and block for the
/// `Activated` ack, whose hash must equal the registry's published
/// witness for the target fine-tune. The actor runs no swap-specific
/// code: per-actor control FIFO plus the staging machinery give the same
/// park/apply semantics a training commit gets.
fn run_swap_script_pipelined<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &Membership,
) -> Result<()> {
    let poll = hub.poll_interval();
    for swap in prepare_swaps(hub)? {
        let target = swap.actor;
        ensure!(
            mem.alive.contains(&target),
            "hot-swap targets actor {target}, which is no longer in the fleet"
        );
        let wire_v = swap.ckpt.version;
        ep.send(target, Msg::Swap { model: swap.model.clone(), version: swap.version })
            .map_err(|_| anyhow!("actor {target} link down announcing swap"))?;
        for seg in split_into_segments(wire_v, &swap.ckpt.bytes, hub.cfg.segment_bytes) {
            ep.send(target, Msg::Seg(seg))
                .map_err(|_| anyhow!("actor {target} link down streaming swap delta"))?;
        }
        ep.send(target, Msg::Commit { version: wire_v })
            .map_err(|_| anyhow!("actor {target} link down committing swap"))?;
        let deadline = Instant::now() + ACK_TIMEOUT;
        loop {
            hub.check_cancel()?;
            match ep.poll(poll) {
                Polled::Event(Event::Msg {
                    actor,
                    msg: Msg::Activated { actor: aa, version, hash },
                }) => {
                    ensure!(aa == actor, "ack from actor {actor} claims actor {aa}");
                    if actor != target {
                        continue; // stale ack from a failed-over actor
                    }
                    ensure!(
                        version == wire_v,
                        "actor {actor} acked v{version} during swap, expected v{wire_v}"
                    );
                    // Bit-exactness across the swap: the retargeted
                    // actor's policy must equal a fresh bootstrap of the
                    // target fine-tune.
                    ensure!(
                        hash == swap.witness,
                        "actor {actor} swap to {}@v{} diverged from the registry witness",
                        swap.model,
                        swap.version
                    );
                    break;
                }
                Polled::Event(Event::Msg { actor, msg: Msg::Bye }) => {
                    ensure!(actor != target, "actor {actor} left mid-swap");
                }
                Polled::Event(Event::Msg { actor, msg }) => {
                    bail!("actor {actor} sent {msg:?} during swap epilogue")
                }
                Polled::Event(Event::Down { actor, reason }) => {
                    ensure!(actor != target, "swap target actor {actor} died: {reason}");
                }
                Polled::TimedOut => {
                    ensure!(
                        Instant::now() < deadline,
                        "actor {target} never acknowledged swap v{wire_v}"
                    );
                }
                Polled::Closed => bail!("transport closed during swap epilogue"),
            }
        }
        hub.emit(SessionEvent::Swapped {
            actor: target,
            model: swap.model,
            version: swap.version,
            bytes: swap.ckpt.payload_bytes(),
        });
    }
    Ok(())
}

/// Block until every slot's results arrived and — when `committing =
/// (version, sent_s)` — every live actor acknowledged the commit with a
/// checksum matching the trainer policy. Lost actors (transport `Down`,
/// graceful `Bye`, or lease expiry on the wall clock) fail over to
/// survivors without aborting the step.
fn collect_step<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    slots: &mut [Slot],
    committing: Option<(u64, f64)>,
    step: u64,
) -> Result<()> {
    let mut want_acks: BTreeSet<u32> = match committing {
        Some(_) => mem.alive.clone(),
        None => BTreeSet::new(),
    };
    let pid_slot: BTreeMap<u64, usize> = slots
        .iter()
        .enumerate()
        .flat_map(|(i, s)| s.job.pids.iter().map(move |&p| (p, i)))
        .collect();
    let poll = hub.poll_interval();
    // A scripted drain of an already-idle actor can be released before
    // any traffic arrives.
    try_release_drained(hub, ep, mem, &want_acks, slots)?;
    // Ack-wait backstop: lease expiry only detects a silent partition
    // while the actor still OWES leased work. Once every slot is done
    // (or when none were dispatched — the epilogue commit) a partitioned
    // actor holds no leases, so an unacked commit would otherwise poll
    // forever. The grace clock starts at the first idle tick after
    // generation completes, so slow generation never eats into it. A
    // joiner mid-bootstrap is covered by the same backstop: its
    // `Activated` admission ack is owed exactly like a commit ack.
    let mut ack_grace: Option<Instant> = None;
    while slots.iter().any(|s| !s.done) || !want_acks.is_empty() || !mem.joining.is_empty() {
        hub.check_cancel()?;
        match ep.poll(poll) {
            Polled::Event(Event::Msg { actor, msg }) => match msg {
                Msg::RolloutResult { actor: ra, prompt_id, version, hash, reward, tokens } => {
                    ensure!(ra == actor, "result from actor {actor} claims actor {ra}");
                    let Some(&si) = pid_slot.get(&prompt_id) else {
                        // A failed-over actor whose link survived (lease
                        // expiry, not crash) may keep emitting results for
                        // prompts that already migrated to another step.
                        ensure!(
                            !mem.alive.contains(&actor),
                            "result for unknown prompt {prompt_id} from live actor {actor}"
                        );
                        continue;
                    };
                    let slot = &mut slots[si];
                    if slot.done || slot.executing != actor {
                        // Stale result: the slot failed over (or already
                        // closed) — its lease is gone, the predicate
                        // would reject it, drop it here.
                        continue;
                    }
                    ensure!(
                        version == slot.job.version,
                        "actor {actor} generated prompt {prompt_id} on v{version}, leased v{}",
                        slot.job.version
                    );
                    match slot.hash {
                        None => slot.hash = Some(hash),
                        Some(h) => ensure!(
                            h == hash,
                            "actor {actor} reported inconsistent checkpoint hashes in one job"
                        ),
                    }
                    slot.results.push(Rollout {
                        prompt_id,
                        actor,
                        version,
                        prompt_tokens: Task::from_prompt_id(prompt_id, hub.cfg.bench)
                            .prompt_tokens(),
                        generated_tokens: tokens,
                        reward,
                    });
                    if slot.results.len() == slot.expect {
                        finalize_slot(hub, slot, step)?;
                    }
                }
                Msg::Activated { actor: aa, version, hash } => {
                    ensure!(aa == actor, "ack from actor {actor} claims actor {aa}");
                    if mem.joining.contains_key(&actor) {
                        // A joiner's first Activated is its admission
                        // request: witness-check, then enter the fleet.
                        admit_joiner(hub, ep, mem, actor, version, hash)?;
                        continue;
                    }
                    if !mem.alive.contains(&actor) {
                        continue; // stale ack from a failed-over actor
                    }
                    let Some((v, sent_s)) = committing else {
                        bail!("unexpected commit ack v{version} from actor {actor}");
                    };
                    if version != v {
                        bail!("actor {actor} committed v{version}, expected v{v}");
                    }
                    // Cross-process bit-exactness at every committed
                    // version: the ack's hash is the actor's post-commit
                    // policy checksum.
                    if hash != hub.accum[(v - 1) as usize].policy_checksum {
                        bail!("actor {actor} diverged from trainer policy at v{version}");
                    }
                    if !want_acks.remove(&actor) {
                        // An ack from an actor we already failed over is
                        // stale, not fatal; a duplicate from a live one
                        // is a protocol bug.
                        ensure!(!mem.alive.contains(&actor), "duplicate commit ack from {actor}");
                        continue;
                    }
                    hub.sched.note_committed(actor, version);
                    let now = hub.now_s();
                    hub.timeline
                        .record(&format!("actor{actor}"), SpanKind::Commit, sent_s, now, step);
                }
                Msg::Join { actor: ja, prior_tau, region } => {
                    ensure!(ja == actor, "join from actor {actor} claims actor {ja}");
                    bootstrap_joiner(hub, ep, mem, actor, prior_tau, region)?;
                }
                Msg::Draining { actor: da } => {
                    ensure!(da == actor, "drain notice from actor {actor} claims actor {da}");
                    // Spot-preemption warning: stop scheduling the actor
                    // and let its in-flight leases race the reclaim. If
                    // it finishes in time it drains cleanly; if the kill
                    // lands first, the Down below is a Preempted failover.
                    if mem.alive.contains(&actor) && mem.warned.insert(actor) {
                        mem.draining.insert(actor);
                        hub.sched.set_alive(actor, false);
                        if hub.cfg.verbose {
                            eprintln!("actor {actor} warned of preemption; draining");
                        }
                        hub.emit(SessionEvent::Preempted { actor });
                    }
                }
                // A Hello after the run started is a stray reconnect
                // attempt; live rejoin runs through Invite/Join with a
                // real bootstrap, so refuse the bare handshake politely
                // (the run continues on survivors).
                Msg::Hello { .. } => {
                    let _ = ep.send(actor, Msg::Bye);
                }
                Msg::Bye => handle_bye(hub, ep, mem, &mut want_acks, slots, actor)?,
                other => bail!("unexpected message from actor {actor}: {other:?}"),
            },
            Polled::Event(Event::Down { actor, reason }) => {
                let why = classify_down(mem, actor, &reason);
                fail_actor(hub, ep, mem, &mut want_acks, slots, actor, why)?;
            }
            Polled::TimedOut => {
                // Idle tick: run the lease-expiry sweep. Under the manual
                // deterministic clock nothing ever expires; on the wall
                // clock this is the paper's implicit failure detector for
                // partitioned (silent) actors.
                expiry_sweep(hub, ep, mem, &mut want_acks, slots)?;
                if slots.iter().all(|s| s.done)
                    && (!want_acks.is_empty() || !mem.joining.is_empty())
                {
                    let now = Instant::now();
                    let deadline = *ack_grace.get_or_insert(now + ACK_TIMEOUT);
                    if now >= deadline {
                        for actor in want_acks.clone() {
                            fail_actor(
                                hub,
                                ep,
                                mem,
                                &mut want_acks,
                                slots,
                                actor,
                                FailReason::Partition,
                            )?;
                        }
                        for actor in mem.joining.keys().copied().collect::<Vec<_>>() {
                            fail_actor(
                                hub,
                                ep,
                                mem,
                                &mut want_acks,
                                slots,
                                actor,
                                FailReason::Partition,
                            )?;
                        }
                    }
                }
            }
            Polled::Closed => bail!("transport closed before step {step} completed"),
        }
        try_release_drained(hub, ep, mem, &want_acks, slots)?;
    }
    Ok(())
}

/// A slot's results are complete: run the shared acceptance/settlement
/// accounting ([`Hub::submit_and_settle`], with the actor-echoed hash)
/// and record the rollout span.
fn finalize_slot<C: Compute>(hub: &mut Hub<C>, slot: &mut Slot, step: u64) -> Result<()> {
    let hash = slot.hash.expect("finalized slot has results");
    // Settle on the full generated token count (work performed), even if
    // some leases lapsed — matching the sequential executor's accounting.
    let tokens: u64 = slot.results.iter().map(|r| r.generated_tokens.len() as u64).sum();
    slot.end_s = hub.now_s();
    hub.submit_and_settle(
        slot.executing,
        &slot.job,
        hash,
        &mut slot.results,
        tokens,
        slot.end_s - slot.start_s,
    )?;
    hub.timeline.record(
        &format!("actor{}", slot.executing),
        SpanKind::Rollout,
        slot.start_s,
        slot.end_s,
        step,
    );
    slot.done = true;
    Ok(())
}

/// Typed cause for a `Down` event: a warned actor was `Preempted`, a
/// draining one `Left`; relay loss and plain crashes fall through on the
/// transport's reason string.
fn classify_down(mem: &Membership, actor: u32, reason: &str) -> FailReason {
    if mem.warned.contains(&actor) {
        FailReason::Preempted
    } else if mem.draining.contains(&actor) {
        FailReason::Left
    } else if reason.contains("relay") {
        FailReason::RelayLost
    } else {
        FailReason::Crash
    }
}

/// In-process relay trees cannot fail a *relay* over: segments queued
/// in its dropped mailbox are gone, so peers mid-staging would wait on
/// a window nobody can retransmit — and their parked commits would
/// never ack. Abort loudly (the pre-failover behavior) instead of
/// hanging; flat InProc, Sim, and Tcp topologies fail over fully.
fn check_relay_loss<C: Compute>(hub: &Hub<C>, actor: u32, why: &str) -> Result<()> {
    if let Some(spec) = &hub.cfg.distribution {
        if !spec.is_flat() && spec.relays().contains(&(actor as usize)) {
            bail!(
                "relay actor {actor} lost mid-run ({why}); in-process relay-tree \
                 failover is unsupported — use a flat topology or --transport sim/tcp"
            );
        }
    }
    Ok(())
}

/// A live actor announced a graceful departure (`Msg::Bye`): hand its
/// leased prompts back without the failover penalty and re-issue them to
/// survivors. Counted as a drain, never a failover.
fn handle_bye<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    want_acks: &mut BTreeSet<u32>,
    slots: &mut [Slot],
    actor: u32,
) -> Result<()> {
    if !mem.alive.remove(&actor) {
        return Ok(()); // duplicate/stale departure notice
    }
    mem.draining.remove(&actor);
    mem.warned.remove(&actor);
    check_relay_loss(hub, actor, "left")?;
    hub.sched.set_alive(actor, false);
    want_acks.remove(&actor);
    ep.set_active(actor, false);
    hub.ledger.revoke_actor_without_penalty(actor);
    if hub.cfg.verbose {
        eprintln!("actor {actor} left gracefully; handing back its leases");
    }
    let requeued_before = hub.requeued;
    reissue_orphans(hub, ep, mem, slots, actor)?;
    hub.emit(SessionEvent::Draining { actor, requeued: hub.requeued - requeued_before });
    Ok(())
}

/// Release scripted drains whose actors are fully idle: no unacked
/// commit, no undone slot on them. The hub sends `Msg::Drain` (zero
/// grace — there is nothing left to wait for) and the worker answers
/// `Bye` and exits cleanly. Counted as a drain with zero requeued work.
fn try_release_drained<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    want_acks: &BTreeSet<u32>,
    slots: &[Slot],
) -> Result<()> {
    let ready: Vec<u32> = mem
        .draining
        .iter()
        .copied()
        .filter(|a| {
            mem.alive.contains(a)
                && !want_acks.contains(a)
                && !slots.iter().any(|s| !s.done && s.executing == *a)
        })
        .collect();
    for actor in ready {
        mem.alive.remove(&actor);
        mem.draining.remove(&actor);
        ep.set_active(actor, false);
        // A failed send means the link died first; the Down event will
        // report it (classified Left — it was already draining).
        let _ = ep.send(actor, Msg::Drain { grace_ms: 0 });
        if hub.cfg.verbose {
            eprintln!("actor {actor} drained; released");
        }
        hub.emit(SessionEvent::Draining { actor, requeued: 0 });
    }
    Ok(())
}

/// Remove a lost actor from the run: revoke its leases, exclude it from
/// scheduling, stop waiting for its acks, and re-issue its unfinished
/// slots to survivors — the §5.4 failover loop, no global restart.
fn fail_actor<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    want_acks: &mut BTreeSet<u32>,
    slots: &mut [Slot],
    actor: u32,
    reason: FailReason,
) -> Result<()> {
    // A joiner that dies mid-bootstrap never held leases or scheduler
    // state: count the failover, drop the bootstrap, move on.
    if let Some(jf) = mem.joining.remove(&actor) {
        // `bootstrap_joiner` pinned the chain when the (announced)
        // delta-chain bootstrap started streaming; release it so gc can
        // move again.
        if jf.announced && matches!(jf.bootstrap, BootstrapKind::DeltaChain) {
            hub.store.unpin_chain(jf.version);
        }
        if !mem.alive.contains(&actor) {
            hub.failures += 1;
            ep.set_active(actor, false);
            if hub.cfg.verbose {
                eprintln!("joiner {actor} lost mid-bootstrap ({reason})");
            }
            hub.emit(SessionEvent::Failover { actor, requeued: 0, reason });
            return Ok(());
        }
    }
    if !mem.alive.remove(&actor) {
        return Ok(()); // duplicate report (write-path cut + reader EOF)
    }
    mem.draining.remove(&actor);
    mem.warned.remove(&actor);
    check_relay_loss(hub, actor, &reason.to_string())?;
    hub.failures += 1;
    hub.sched.set_alive(actor, false);
    want_acks.remove(&actor);
    ep.set_active(actor, false);
    // Lease hygiene: expiry would reclaim these anyway; an explicit
    // failure signal just shortens the window.
    hub.ledger.revoke_actor(actor);
    if hub.cfg.verbose {
        eprintln!("actor {actor} lost ({reason}); failing over");
    }
    let requeued_before = hub.requeued;
    reissue_orphans(hub, ep, mem, slots, actor)?;
    hub.emit(SessionEvent::Failover { actor, requeued: hub.requeued - requeued_before, reason });
    Ok(())
}

/// Re-lease a lost actor's unfinished slots to the lowest-numbered
/// non-draining survivor (deterministic choice), preserving each job's
/// prompt order and RNG seed so the regenerated rollouts are
/// bit-identical.
fn reissue_orphans<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &Membership,
    slots: &mut [Slot],
    dead: u32,
) -> Result<()> {
    for slot in slots.iter_mut().filter(|s| !s.done && s.executing == dead) {
        let Some(&survivor) = mem.alive.iter().find(|a| !mem.draining.contains(a)) else {
            bail!("actor {dead} failed with no survivors to absorb its work");
        };
        let now = hub.lease_now();
        let leased =
            hub.ledger.reissue(&slot.job.pids, survivor, slot.job.version, slot.job.hash, now);
        ensure!(
            leased.len() == slot.job.pids.len(),
            "failover re-leased {}/{} prompts of actor {dead}",
            leased.len(),
            slot.job.pids.len()
        );
        slot.executing = survivor;
        slot.results.clear();
        slot.hash = None;
        slot.start_s = hub.now_s();
        hub.requeued += slot.job.pids.len() as u64;
        ep.send(
            survivor,
            Msg::Job {
                version: slot.job.version,
                rng_seed: slot.job.rng_seed,
                prompt_ids: slot.job.pids.clone(),
            },
        )
        .map_err(|_| anyhow!("survivor {survivor} link down during failover"))?;
    }
    Ok(())
}

/// Expire overdue leases on the run clock. Slots whose prompts lapsed
/// mean the executing actor stalled or was partitioned away (its sockets
/// may still be open — only the lease can tell): declare it failed and
/// migrate the work.
fn expiry_sweep<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    want_acks: &mut BTreeSet<u32>,
    slots: &mut [Slot],
) -> Result<()> {
    let now = hub.clock.now();
    let expired = hub.ledger.expire(now);
    if expired.is_empty() {
        return Ok(());
    }
    let stalled: BTreeSet<u32> = slots
        .iter()
        .filter(|s| !s.done && s.job.pids.iter().any(|p| expired.contains(p)))
        .map(|s| s.executing)
        .collect();
    for actor in stalled {
        fail_actor(hub, ep, mem, want_acks, slots, actor, FailReason::Stall)?;
    }
    Ok(())
}

/// Fire the scripted membership changes pinned to the hub's current
/// version: invite joiners (they bootstrap and get admitted inside the
/// following `collect_step`), start scripted drains, and give the
/// cost-model autoscaler its say at the same boundary.
fn run_membership_script<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
) -> Result<()> {
    let v = hub.version;
    let joins: Vec<JoinSpec> =
        hub.cfg.elastic.joins.iter().copied().filter(|j| j.at_version == v).collect();
    for js in joins {
        if mem.alive.contains(&js.actor) || mem.joining.contains_key(&js.actor) {
            continue;
        }
        ep.send(js.actor, Msg::Invite { actor: js.actor })
            .map_err(|_| anyhow!("scripted joiner {} unreachable at invite", js.actor))?;
        mem.joining.insert(
            js.actor,
            JoinInFlight {
                version: v,
                bootstrap: js.bootstrap,
                bytes: 0,
                prior_tau: 1000.0,
                region: 0,
                announced: false,
            },
        );
        if hub.cfg.verbose {
            eprintln!("invited joiner {} at v{v} ({})", js.actor, js.bootstrap.name());
        }
    }
    let leaves: Vec<LeaveSpec> =
        hub.cfg.elastic.leaves.iter().copied().filter(|l| l.at_version == v).collect();
    for ls in leaves {
        if mem.alive.contains(&ls.actor) && mem.draining.insert(ls.actor) {
            hub.sched.set_alive(ls.actor, false);
            if hub.cfg.verbose {
                eprintln!("draining actor {} at v{v} (scripted leave)", ls.actor);
            }
        }
    }
    autoscale_tick(hub, mem);
    Ok(())
}

/// An invited actor announced itself (`Msg::Join`): ship it the active
/// policy. Delta-chain bootstrap replays `D_1..D_v` from the checkpoint
/// store through the actor's ordinary staging decoder; snapshot
/// bootstrap sends the dense policy in one message. Either way the
/// joiner's `Activated` ack carries its SHA-256 policy witness, checked
/// in [`admit_joiner`] before it gets its first lease.
fn bootstrap_joiner<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    actor: u32,
    prior_tau: f64,
    region: u32,
) -> Result<()> {
    {
        let jf = mem
            .joining
            .get_mut(&actor)
            .ok_or_else(|| anyhow!("uninvited join announcement from actor {actor}"))?;
        ensure!(!jf.announced, "duplicate join announcement from actor {actor}");
        jf.announced = true;
        jf.prior_tau = prior_tau;
        jf.region = region;
    }
    let v = mem.joining[&actor].version;
    ensure!(
        v == hub.version,
        "joiner {actor} invited at v{v} but hub moved to v{}",
        hub.version
    );
    let mut sent: u64 = 0;
    match mem.joining[&actor].bootstrap {
        BootstrapKind::Snapshot => {
            let data = hub.policy.to_snapshot_bytes();
            sent += data.len() as u64;
            ep.send(actor, Msg::Snapshot { version: v, hash: hub.version_hash, data })
                .map_err(|_| anyhow!("joiner {actor} link down during snapshot bootstrap"))?;
        }
        BootstrapKind::DeltaChain => {
            // Pin the chain horizon first: a gc sweep must not reclaim
            // D_1..D_v while this bootstrap is in flight (released in
            // `admit_joiner`, or in `fail_actor` if the joiner dies).
            hub.store.pin_chain(v);
            // Prefer one bit-exact folded delta (last-writer-wins merge
            // of D_1..D_v): the same end state in O(changed elements)
            // bytes instead of O(chain bytes), and one decode on the
            // joiner. Fall back to per-version replay when the chain
            // cannot fold (additive mode, decode failure) — the joiner's
            // staging decoder handles both identically.
            match fold_chain_for_bootstrap(hub, v) {
                Some(folded) => {
                    sent += folded.payload_bytes();
                    for seg in split_into_segments(v, &folded.bytes, hub.cfg.segment_bytes) {
                        ep.send(actor, Msg::Seg(seg)).map_err(|_| {
                            anyhow!("joiner {actor} link down during folded-chain bootstrap")
                        })?;
                    }
                }
                None => {
                    for ver in 1..=v {
                        let ckpt = hub
                            .store
                            .get(ver)
                            .ok_or_else(|| anyhow!("delta chain broken: D_{ver} not in store"))?;
                        sent += ckpt.payload_bytes();
                        for seg in split_into_segments(ver, &ckpt.bytes, hub.cfg.segment_bytes) {
                            ep.send(actor, Msg::Seg(seg)).map_err(|_| {
                                anyhow!("joiner {actor} link down during chain replay")
                            })?;
                        }
                    }
                }
            }
            ep.send(actor, Msg::Commit { version: v })
                .map_err(|_| anyhow!("joiner {actor} link down during chain replay"))?;
        }
    }
    let jf = mem.joining.get_mut(&actor).expect("still joining");
    jf.bytes += sent;
    if hub.cfg.verbose {
        eprintln!("bootstrapping joiner {actor} to v{v}: {sent} B ({})", jf.bootstrap.name());
    }
    Ok(())
}

/// Fold `D_1..D_v` from the hub's in-memory store into one sealed
/// checkpoint for delta-chain bootstrap — the same bit-exact merge the
/// durable store's offline compaction uses ([`merge_chain`]). `None`
/// when the chain cannot fold (missing link, decode failure, additive
/// mode); the caller falls back to per-version replay.
fn fold_chain_for_bootstrap<C: Compute>(hub: &Hub<C>, v: u64) -> Option<DeltaCheckpoint> {
    let mut chain: Vec<SparseDelta> = Vec::with_capacity(v as usize);
    for ver in 1..=v {
        chain.push(hub.store.get(ver)?.open().ok()?);
    }
    let folded = merge_chain(&chain).ok()?;
    Some(DeltaCheckpoint::seal(&folded))
}

/// A bootstrapping joiner echoed `Activated`: verify its SHA-256 policy
/// witness against the trainer's committed checksum, then admit it to
/// the scheduler, the broadcast fan-out, and the lease pool.
fn admit_joiner<C: Compute>(
    hub: &mut Hub<C>,
    ep: &mut dyn HubEndpoint,
    mem: &mut Membership,
    actor: u32,
    version: u64,
    hash: [u8; 32],
) -> Result<()> {
    let jf = &mem.joining[&actor];
    ensure!(jf.announced, "joiner {actor} acked before announcing itself");
    ensure!(
        version == jf.version,
        "joiner {actor} activated v{version}, bootstrap targeted v{}",
        jf.version
    );
    ensure!(
        version >= 1 && hash == hub.accum[(version - 1) as usize].policy_checksum,
        "joiner {actor} diverged from trainer policy at v{version}"
    );
    let jf = mem.joining.remove(&actor).expect("checked above");
    if matches!(jf.bootstrap, BootstrapKind::DeltaChain) {
        // The bootstrap landed; its chain horizon no longer needs gc
        // protection.
        hub.store.unpin_chain(jf.version);
    }
    hub.sched.admit(actor, jf.prior_tau, version, jf.region as usize);
    mem.alive.insert(actor);
    ep.set_active(actor, true);
    if hub.cfg.verbose {
        eprintln!("joiner {actor} admitted at v{version} ({} B)", jf.bytes);
    }
    hub.emit(SessionEvent::Joined { actor, version, bootstrap: jf.bootstrap, bytes: jf.bytes });
    Ok(())
}

/// Advisory autoscaler tick: price the live fleet against the reserved
/// RDMA tokens-per-dollar line using the previous step's measured
/// generation throughput and delta egress, and emit the typed decision.
/// Purely observational — scripted membership stays the only mutator,
/// so decisions never perturb determinism.
fn autoscale_tick<C: Compute>(hub: &mut Hub<C>, mem: &Membership) {
    if !hub.cfg.elastic.autoscale || hub.version == 0 || mem.alive.is_empty() {
        return;
    }
    let v = hub.version;
    let a = hub.accum[(v - 1) as usize];
    let n_alive = mem.alive.len();
    let mean_tau = mem
        .alive
        .iter()
        .map(|&x| hub.sched.tau(x).unwrap_or(1000.0))
        .sum::<f64>()
        / n_alive as f64;
    let per_actor = (a.gen_tokens as f64 / n_alive as f64) / mean_tau.max(1e-9);
    let step_s = mean_tau.max(1e-3);
    let fleet_tps = a.gen_tokens as f64 / step_s;
    let line = reserved_line(&hub.cfg.model, fleet_tps).unwrap_or_else(|| {
        Deployment::reserved_rdma("reserve-line", GpuClass::H100, 8).tokens_per_dollar(fleet_tps)
    });
    let decision = Autoscaler::new(1, line).decide(n_alive, per_actor, a.payload_bytes, step_s);
    hub.emit(SessionEvent::Autoscale { version: v, decision });
}
