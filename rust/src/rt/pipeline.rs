//! Overlapped one-step async runtime (paper §2.1, Fig 7, §5.2).
//!
//! The paper's throughput claim rests on *hiding* synchronization inside
//! the generation window: while actors generate batch `s` on the stale
//! policy `v_{s-1}`, the Trainer Hub trains on batch `s-1`, extracts and
//! streams `D_{v_s}` into every actor's staging decoder mid-generation,
//! and Commit lands at each actor's next safe point (between generation
//! batches) — no global barrier. This module implements that schedule
//! twice over the *same* step logic:
//!
//! * [`ExecMode::Sequential`] — every phase in program order on one
//!   thread (the reference executor; wall-clock is the sum of phases);
//! * [`ExecMode::Pipelined`] — one worker thread per actor, each owning
//!   its [`PolicyState`] behind an mpsc command mailbox, with the hub
//!   thread training/streaming concurrently with generation.
//!
//! Both executors share `plan_step` / `run_gen_job` / `train_and_stream`,
//! draw per-(step, actor) RNG streams, and assemble training batches in
//! assignment order, so with `LocalRunConfig::deterministic` the two modes
//! are **bit-identical**: same committed policies, same per-step rho and
//! payload bytes, same final version (see `tests/pipeline_equivalence.rs`).
//! Bit-exactness of actor policies against the trainer is asserted at
//! every committed version in both modes — cross-thread via a SHA-256
//! witness ([`policy_checksum`]) carried in the Commit acknowledgement.
//!
//! Why the overlap is legal: a generation job snapshots the actor's params
//! at job start, so a Commit applying between generation batches never
//! changes in-flight completions — it only moves the *next* job onto the
//! new version, exactly the paper's staged-activation contract.

use crate::actor::rollout::SampleCfg;
use crate::actor::{CommitResult, PolicyState};
use crate::data::{pack_batch, Task};
use crate::delta::{CheckpointStore, ModelLayout, ParamSet};
use crate::ledger::{JobLedger, LeasePolicy, Reject, WallClock};
use crate::metrics::{SpanKind, Timeline};
use crate::rt::compute::Compute;
use crate::rt::local::{LocalRunConfig, RunReport, StepLog};
use crate::runtime::TrainState;
use crate::scheduler::{Assignment, Scheduler, SchedulerConfig, VersionState};
use crate::trainer::{group_advantages, stream_checkpoint, Rollout};
use crate::transport::Segment;
use crate::util::Rng;
use anyhow::{anyhow, bail, Result};
use sha2::{Digest, Sha256};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::Instant;

/// Geo-distribution wiring for the runtime: actors grouped into regions,
/// one relay per region. The hub streams each delta segment once per
/// region — to the relay's mailbox — and the relay worker forwards it to
/// its regional peers cut-through, mirroring
/// [`crate::transport::DistributionPlan`]'s tree inside one process.
/// Commits still go hub→actor directly, so on multi-hop paths a
/// `Commit(v)` can overtake `D_v` segments; `PolicyState` parks such
/// commits until staging completes (see `actor::mod`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DistributionSpec {
    /// Region index of each actor, in actor order (empty = flat hub→all).
    pub region_of: Vec<usize>,
}

impl DistributionSpec {
    /// Derive the runtime wiring from a transport-layer plan.
    pub fn from_plan(plan: &crate::transport::DistributionPlan) -> DistributionSpec {
        DistributionSpec { region_of: plan.region_map() }
    }

    pub fn is_flat(&self) -> bool {
        self.region_of.is_empty()
    }

    pub fn n_regions(&self) -> usize {
        self.region_of.iter().max().map_or(0, |m| m + 1)
    }

    /// The relay (first actor) of each region, by region index.
    pub fn relays(&self) -> Vec<usize> {
        (0..self.n_regions())
            .filter_map(|r| self.region_of.iter().position(|&x| x == r))
            .collect()
    }

    /// Actors relay `actor` forwards segments to: its region's non-relay
    /// members, when `actor` is that region's relay; empty otherwise.
    pub fn forward_targets(&self, actor: usize) -> Vec<usize> {
        let Some(&region) = self.region_of.get(actor) else {
            return Vec::new();
        };
        let relay = self.region_of.iter().position(|&x| x == region);
        if relay != Some(actor) {
            return Vec::new();
        }
        self.region_of
            .iter()
            .enumerate()
            .filter(|&(i, &r)| r == region && i != actor)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Executor choice for the local runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Phase-sequential reference executor (rollout, train, extract,
    /// commit in program order on one thread).
    Sequential,
    /// One worker thread per actor; training + delta streaming overlap
    /// generation; commits land at per-actor safe points.
    Pipelined,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Pipelined => "pipelined",
        }
    }
}

/// SHA-256 over the policy's bf16 bits in layout order — the witness the
/// pipelined runtime ships across threads to assert actor == trainer
/// bit-exactness at every committed version.
pub fn policy_checksum(p: &ParamSet) -> [u8; 32] {
    let mut h = Sha256::new();
    let mut buf: Vec<u8> = Vec::new();
    for t in &p.tensors {
        buf.clear();
        buf.reserve(t.len() * 2);
        for b in t {
            buf.extend_from_slice(&b.to_bits().to_le_bytes());
        }
        h.update(&buf);
    }
    h.finalize()
}

/// Independent RNG stream per (seed, step, actor): generation draws the
/// same randomness in both executors regardless of thread interleaving.
fn job_seed(seed: u64, step: u64, actor: u32) -> u64 {
    let mut x = seed ^ 0x9E37_79B9_7F4A_7C15;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9).wrapping_add(step);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ ((actor as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// One actor's generation work for one step.
#[derive(Clone, Debug)]
struct GenJob {
    step: u64,
    /// Policy version the rollouts must be generated on (the lease's v).
    version: u64,
    /// Integrity hash of that version's checkpoint (the lease's h).
    hash: [u8; 32],
    /// Claimed prompt ids, in lease order.
    pids: Vec<u64>,
    rng_seed: u64,
}

/// Hub -> actor mailbox protocol. Channel FIFO order is the correctness
/// backbone: segments of `D_v` always precede `Commit(v)`, which always
/// precedes `Generate` for the step that needs `v` active.
enum ToActor {
    Generate(GenJob),
    /// Delta segment for the staging decoder (consumed mid-generation).
    Segment(Segment),
    /// Activate `version` at the next safe point.
    Commit(u64),
}

/// Actor -> hub replies. Span timestamps are seconds since the RL phase
/// origin, measured on the worker.
enum FromActor {
    Generated {
        actor: u32,
        step: u64,
        rollouts: Vec<Rollout>,
        gen_tokens: u64,
        start_s: f64,
        end_s: f64,
    },
    Committed {
        actor: u32,
        version: u64,
        checksum: [u8; 32],
        start_s: f64,
        end_s: f64,
    },
    Failed {
        actor: u32,
        msg: String,
    },
}

/// Run one generation job against `state`. Params are snapshotted at
/// entry; `at_safe_point` fires between generation batches so staging and
/// deferred commits can land mid-step without touching in-flight output.
fn run_gen_job<C: Compute>(
    comp: &C,
    cfg: &LocalRunConfig,
    state: &mut PolicyState,
    actor: u32,
    job: &GenJob,
    mut at_safe_point: impl FnMut(&mut PolicyState) -> Result<(), String>,
) -> Result<(Vec<Rollout>, u64), String> {
    if state.active_version() != job.version {
        return Err(format!(
            "actor {actor}: generate for v{} but active is v{}",
            job.version,
            state.active_version()
        ));
    }
    let shape = comp.shape();
    let policy_ref = state.params().clone();
    let mut rng = Rng::new(job.rng_seed);
    let mut rollouts = Vec::with_capacity(job.pids.len() * cfg.group_size);
    let mut gen_tokens = 0u64;
    let sample = SampleCfg { temperature: cfg.temperature, max_new_tokens: cfg.max_new_tokens };
    for chunk in job.pids.chunks((shape.b_gen / cfg.group_size).max(1)) {
        state.set_generating(true);
        let mut prompts = Vec::with_capacity(chunk.len() * cfg.group_size);
        for &pid in chunk {
            let task = Task::from_prompt_id(pid, cfg.bench);
            for _ in 0..cfg.group_size {
                prompts.push(task.prompt_tokens());
            }
        }
        let gens = comp
            .generate(&policy_ref, &prompts, sample, &mut rng)
            .map_err(|e| format!("actor {actor} generate: {e:#}"));
        state.set_generating(false);
        let gens = gens?;
        for (gi, g) in gens.iter().enumerate() {
            let pid = chunk[gi / cfg.group_size];
            let task = Task::from_prompt_id(pid, cfg.bench);
            let completion = &g.tokens[g.prompt_len..];
            gen_tokens += completion.len() as u64;
            rollouts.push(Rollout {
                prompt_id: pid,
                actor,
                version: job.version,
                prompt_tokens: g.tokens[..g.prompt_len].to_vec(),
                generated_tokens: completion.to_vec(),
                reward: task.reward(completion),
            });
        }
        // Inter-batch safe point: drain staging segments / commits.
        at_safe_point(state)?;
    }
    Ok((rollouts, gen_tokens))
}

/// Per-step record assembled across loop iterations (generation lands a
/// step before its training under the one-step-off schedule).
#[derive(Clone, Copy, Default)]
struct StepAccum {
    mean_reward: f32,
    gen_tokens: u64,
    rollout_ms: f64,
    loss: f32,
    train_ms: f64,
    extract_ms: f64,
    rho: f64,
    payload_bytes: u64,
    policy_checksum: [u8; 32],
}

/// Lease/ledger time source: wall clock for real runs, a deterministic
/// tick counter when `LocalRunConfig::deterministic` (ticks are µs-scale,
/// so leases — floored at seconds — never expire and both executors
/// accept identical rollout sets).
enum RunClock {
    Real(WallClock),
    Virtual(f64),
}

impl RunClock {
    fn now(&mut self) -> f64 {
        match self {
            RunClock::Real(w) => w.now(),
            RunClock::Virtual(t) => {
                *t += 1e-6;
                *t
            }
        }
    }
}

/// Trainer-hub state shared by both executors.
struct Hub<'a, C: Compute> {
    cfg: &'a LocalRunConfig,
    layout: &'a ModelLayout,
    comp: &'a C,
    state: TrainState,
    /// Trainer policy snapshot at `version`.
    policy: ParamSet,
    version: u64,
    version_hash: [u8; 32],
    store: CheckpointStore,
    ledger: JobLedger,
    sched: Scheduler,
    clock: RunClock,
    timeline: Timeline,
    /// RL-phase origin for timeline spans.
    t0: Instant,
    task_counter: u64,
    prompts_per_step: usize,
    accum: Vec<StepAccum>,
}

impl<'a, C: Compute> Hub<'a, C> {
    fn new(
        cfg: &'a LocalRunConfig,
        layout: &'a ModelLayout,
        comp: &'a C,
        state: TrainState,
        task_counter: u64,
    ) -> Hub<'a, C> {
        let policy = state.to_policy();
        let mut sched = Scheduler::new(SchedulerConfig::default());
        for i in 0..cfg.n_actors {
            sched.register(i as u32, 1000.0);
            sched.observe_version(i as u32, VersionState { active: 0, staged: None });
        }
        // Region tags / the bandwidth-aware allocation gate are not wired
        // here: in-process streaming has no per-region WAN timings to
        // observe (and feeding wall-clock stream durations would break the
        // deterministic executor-equivalence contract). The gate runs
        // where real link timings exist: the netsim driver
        // (`SimConfig::bandwidth_gate`) and `sparrowrl exp wan`.
        let clock = if cfg.deterministic {
            RunClock::Virtual(0.0)
        } else {
            RunClock::Real(WallClock::start())
        };
        Hub {
            cfg,
            layout,
            comp,
            state,
            policy,
            version: 0,
            // Version-0 "hash": the genesis policy has no checkpoint.
            version_hash: [0u8; 32],
            store: CheckpointStore::in_memory(),
            ledger: JobLedger::new(LeasePolicy::default()),
            sched,
            clock,
            timeline: Timeline::default(),
            t0: Instant::now(),
            task_counter,
            prompts_per_step: comp.shape().b_train / cfg.group_size,
            accum: vec![StepAccum::default(); cfg.steps as usize],
        }
    }

    fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Post this step's prompts and lease them out per Algorithm 1,
    /// against the *current* committed version (one step stale relative
    /// to the version being trained concurrently).
    fn plan_step(&mut self, step: u64) -> Result<Vec<(Assignment, GenJob)>> {
        let pids: Vec<u64> = (0..self.prompts_per_step)
            .map(|_| {
                self.task_counter += 1;
                self.task_counter
            })
            .collect();
        self.ledger.post(pids.iter().copied());
        let now = self.clock.now();
        // Real-clock lease hygiene: reclaim anything overdue from stalled
        // or crashed in-flight work before allocating.
        self.ledger.expire(now);
        let assignments = self.sched.allocate(self.version, self.prompts_per_step as u64);
        if assignments.is_empty() {
            bail!("no eligible actors at step {step}");
        }
        let mut out = Vec::with_capacity(assignments.len());
        for asg in assignments {
            let claimed =
                self.ledger
                    .issue(asg.actor, self.version, self.version_hash, now, asg.requests as usize);
            let job = GenJob {
                step,
                version: self.version,
                hash: self.version_hash,
                pids: claimed,
                rng_seed: job_seed(self.cfg.seed, step, asg.actor),
            };
            out.push((asg, job));
        }
        Ok(out)
    }

    /// Submit one assignment's results under the acceptance predicate and
    /// settle the scheduler with *per-assignment* tokens and duration (the
    /// old loop credited cumulative totals across actors, corrupting tau).
    /// Returns with `rollouts` filtered down to the accepted prompts: under
    /// real-clock leases, work that outlived its lease is dropped (the
    /// prompts return to the pool via `expire`) instead of killing the run.
    fn submit_and_settle(
        &mut self,
        actor: u32,
        job: &GenJob,
        rollouts: &mut Vec<Rollout>,
        tokens: u64,
        elapsed_s: f64,
    ) -> Result<()> {
        let now = self.clock.now();
        let mut expired: Vec<u64> = Vec::new();
        for &pid in &job.pids {
            match self.ledger.submit(actor, pid, job.version, job.hash, now) {
                Ok(()) => {}
                Err(Reject::LeaseExpired) => expired.push(pid),
                Err(e) => bail!("ledger rejected {pid}: {e:?}"),
            }
        }
        if !expired.is_empty() {
            rollouts.retain(|r| !expired.contains(&r.prompt_id));
        }
        let dt = if self.cfg.deterministic {
            // Virtual duration pinned to the current estimate: tau stays at
            // its prior, so allocation is identical across executors.
            (tokens as f64 / self.sched.tau(actor).unwrap_or(1.0).max(1e-9)).max(1e-6)
        } else {
            elapsed_s.max(1e-3)
        };
        self.sched.settle(actor, tokens, dt);
        Ok(())
    }

    /// Close out a step's generation accounting.
    fn finish_generation(&mut self, step: u64, batch: &[Rollout], rollout_ms: f64) {
        let a = &mut self.accum[step as usize];
        a.mean_reward = batch.iter().map(|r| r.reward).sum::<f32>() / batch.len().max(1) as f32;
        a.gen_tokens = batch.iter().map(|r| r.generated_tokens.len() as u64).sum();
        a.rollout_ms = rollout_ms;
    }

    /// Train on `batch_step`'s rollouts, then run the fused delta
    /// extract+encode+segment pass, handing each wire-ready segment to
    /// `sink` (the staging path) mid-scan. Advances the trainer-side
    /// version; actor commits are the caller's job.
    fn train_and_stream<F: FnMut(Segment)>(
        &mut self,
        batch_step: u64,
        batch: &[Rollout],
        mut sink: F,
    ) -> Result<()> {
        let shape = self.comp.shape();
        let adv = group_advantages(batch, self.cfg.algorithm);
        let pairs: Vec<(Vec<i32>, Vec<i32>)> = batch
            .iter()
            .map(|r| (r.prompt_tokens.clone(), r.generated_tokens.clone()))
            .collect();
        let packed = pack_batch(&pairs, shape.b_train, shape.max_seq);
        let mut adv_padded = vec![0.0f32; shape.b_train];
        adv_padded[..adv.len()].copy_from_slice(&adv);

        let train_start = self.now_s();
        let t_train = Instant::now();
        let loss = self.comp.train_step(
            &mut self.state,
            &packed.tokens,
            &packed.gen_mask,
            &adv_padded,
            self.cfg.lr_rl,
        )?;
        let train_ms = t_train.elapsed().as_secs_f64() * 1e3;
        let train_end = self.now_s();
        self.timeline.record("trainer", SpanKind::Train, train_start, train_end, batch_step);

        let extract_start = self.now_s();
        let t_extract = Instant::now();
        let new_policy = self.state.to_policy();
        let t0c = self.t0;
        let mut first_seg: Option<f64> = None;
        let mut last_seg = extract_start;
        let (ckpt, stats) = stream_checkpoint(
            self.layout,
            &self.policy,
            &new_policy,
            self.version,
            self.version + 1,
            self.cfg.segment_bytes,
            |seg| {
                let now = t0c.elapsed().as_secs_f64();
                first_seg.get_or_insert(now);
                last_seg = now;
                sink(seg);
            },
        );
        let extract_ms = t_extract.elapsed().as_secs_f64() * 1e3;
        self.timeline.record("trainer", SpanKind::Extract, extract_start, self.now_s(), batch_step);
        if let Some(f) = first_seg {
            self.timeline.record("transfer", SpanKind::Transfer, f, last_seg, batch_step);
        }

        let rho = stats.nnz as f64 / self.layout.total_params() as f64;
        let payload = ckpt.payload_bytes();
        let hash = ckpt.hash;
        self.store.put(ckpt)?;
        self.version += 1;
        self.version_hash = hash;
        self.policy = new_policy;

        let a = &mut self.accum[batch_step as usize];
        a.loss = loss;
        a.train_ms = train_ms;
        a.extract_ms = extract_ms;
        a.rho = rho;
        a.payload_bytes = payload;
        a.policy_checksum = policy_checksum(&self.policy);
        if self.cfg.verbose {
            println!(
                "step {:>3}  loss {:>8.4}  reward {:>5.3}  rho {:>7.4}%  payload {:>10}  ({}x smaller)  gen {:>5} tok",
                batch_step,
                a.loss,
                a.mean_reward,
                a.rho * 100.0,
                crate::util::fmt_bytes(a.payload_bytes),
                self.layout.dense_bytes_bf16() / a.payload_bytes.max(1),
                a.gen_tokens,
            );
        }
        Ok(())
    }

    fn into_report(self, sft_losses: Vec<f32>, wall0: Instant) -> RunReport {
        let dense = self.layout.dense_bytes_bf16();
        let steps = self
            .accum
            .iter()
            .enumerate()
            .map(|(i, a)| StepLog {
                step: i as u64,
                loss: a.loss,
                mean_reward: a.mean_reward,
                rho: a.rho,
                payload_bytes: a.payload_bytes,
                dense_bytes: dense,
                gen_tokens: a.gen_tokens,
                extract_ms: a.extract_ms,
                train_ms: a.train_ms,
                rollout_ms: a.rollout_ms,
                policy_checksum: a.policy_checksum,
            })
            .collect();
        RunReport {
            sft_losses,
            steps,
            final_version: self.version,
            wall_s: wall0.elapsed().as_secs_f64(),
            timeline: self.timeline,
        }
    }
}

/// Run the full loop (SFT warmup + RL) on any [`Compute`] backend.
/// `layout` must match the backend's parameter geometry.
pub fn run_with_compute<C: Compute>(
    cfg: &LocalRunConfig,
    layout: &ModelLayout,
    comp: &C,
    mode: ExecMode,
) -> Result<RunReport> {
    let wall0 = Instant::now();
    let shape = comp.shape();
    if cfg.group_size == 0 || cfg.group_size > shape.b_gen {
        bail!("group_size {} must be in 1..={}", cfg.group_size, shape.b_gen);
    }
    if cfg.group_size > shape.b_train {
        bail!("group_size {} exceeds b_train {}", cfg.group_size, shape.b_train);
    }
    if cfg.n_actors == 0 {
        bail!("need at least one actor");
    }
    if let Some(spec) = &cfg.distribution {
        if !spec.is_flat() && spec.region_of.len() != cfg.n_actors {
            bail!(
                "distribution spec covers {} actors but n_actors is {}",
                spec.region_of.len(),
                cfg.n_actors
            );
        }
    }
    let mut rng = Rng::new(cfg.seed);
    let mut state = TrainState::init(layout, &mut rng);

    // ---------------- SFT warmup: same train path, adv = 1 --------------
    let mut sft_losses = Vec::new();
    let mut task_counter: u64 = 0;
    for _ in 0..cfg.sft_steps {
        let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..shape.b_train)
            .map(|_| {
                task_counter += 1;
                let task = Task::from_prompt_id(task_counter, cfg.bench);
                (task.prompt_tokens(), task.answer_tokens())
            })
            .collect();
        let batch = pack_batch(&pairs, shape.b_train, shape.max_seq);
        let adv = vec![1.0f32; shape.b_train];
        let loss = comp.train_step(&mut state, &batch.tokens, &batch.gen_mask, &adv, cfg.lr_sft)?;
        sft_losses.push(loss);
    }

    // ---------------- RL phase ------------------------------------------
    let mut hub = Hub::new(cfg, layout, comp, state, task_counter);
    match mode {
        ExecMode::Sequential => run_sequential(&mut hub)?,
        ExecMode::Pipelined => run_pipelined(&mut hub)?,
    }
    Ok(hub.into_report(sft_losses, wall0))
}

/// Stream `D_{v}` into in-process actors and commit at their safe points
/// (the sequential executor's staging+commit tail for one version).
fn seq_stream_and_commit<C: Compute>(
    hub: &mut Hub<C>,
    actors: &mut [PolicyState],
    batch_step: u64,
    batch: &[Rollout],
) -> Result<()> {
    let mut stream_err: Option<String> = None;
    let last = actors.len() - 1;
    hub.train_and_stream(batch_step, batch, |seg| {
        for (i, actor) in actors[..last].iter_mut().enumerate() {
            if let Err(e) = actor.on_segment(seg.clone()) {
                stream_err.get_or_insert(format!("actor {i} staging: {e}"));
            }
        }
        if let Err(e) = actors[last].on_segment(seg) {
            stream_err.get_or_insert(format!("actor {last} staging: {e}"));
        }
    })?;
    if let Some(e) = stream_err {
        bail!("{e}");
    }
    let v = hub.version;
    for (i, actor) in actors.iter_mut().enumerate() {
        hub.sched.note_staged(i as u32, v);
        let c0 = hub.t0.elapsed().as_secs_f64();
        match actor.request_commit(v) {
            CommitResult::Applied => {}
            other => bail!("actor {i} commit failed: {other:?}"),
        }
        let c1 = hub.t0.elapsed().as_secs_f64();
        hub.timeline.record(&format!("actor{i}"), SpanKind::Commit, c0, c1, batch_step);
        // Bit-exactness: every actor's policy equals the trainer's.
        if actor.params() != &hub.policy {
            bail!("actor {i} diverged from trainer policy at v{v}");
        }
        hub.sched.note_committed(i as u32, v);
    }
    Ok(())
}

/// Phase-sequential executor over the shared one-step-off schedule.
fn run_sequential<C: Compute>(hub: &mut Hub<C>) -> Result<()> {
    let mut actors: Vec<PolicyState> = (0..hub.cfg.n_actors)
        .map(|_| PolicyState::new(hub.layout.clone(), hub.policy.clone(), 0))
        .collect();
    let mut pending: Option<(u64, Vec<Rollout>)> = None;
    for step in 0..hub.cfg.steps {
        let jobs = hub.plan_step(step)?;
        let phase_t = Instant::now();
        let mut batch: Vec<Rollout> = Vec::new();
        for (asg, job) in &jobs {
            let a = asg.actor as usize;
            let start_s = hub.now_s();
            let t_job = Instant::now();
            let (mut rollouts, tokens) =
                run_gen_job(hub.comp, hub.cfg, &mut actors[a], asg.actor, job, |_| Ok(()))
                    .map_err(anyhow::Error::msg)?;
            let elapsed = t_job.elapsed().as_secs_f64();
            let end_s = hub.now_s();
            hub.timeline.record(&format!("actor{a}"), SpanKind::Rollout, start_s, end_s, step);
            hub.submit_and_settle(asg.actor, job, &mut rollouts, tokens, elapsed)?;
            batch.extend(rollouts);
        }
        hub.finish_generation(step, &batch, phase_t.elapsed().as_secs_f64() * 1e3);
        // Train on the previous batch — after this step's generation, the
        // same dependency order the pipelined executor overlaps.
        if let Some((prev_step, prev)) = pending.take() {
            seq_stream_and_commit(hub, &mut actors, prev_step, &prev)?;
        }
        pending = Some((step, batch));
    }
    if let Some((prev_step, prev)) = pending.take() {
        seq_stream_and_commit(hub, &mut actors, prev_step, &prev)?;
    }
    Ok(())
}

/// Forward one segment to every downstream mailbox (regional relay duty:
/// cut-through, before local staging, so peers never wait on the relay's
/// own decode). Send failures mean the peer exited; its own error path
/// reports the cause, so drops here are not amplified.
fn forward_segment(forwards: &[Sender<ToActor>], seg: &Segment) {
    for tx in forwards {
        let _ = tx.send(ToActor::Segment(seg.clone()));
    }
}

/// Drain an actor's mailbox, then let any parked commit land if we are at
/// a safe point. Segments stage regardless of the generating flag (and are
/// forwarded first when this actor relays for its region); a `Commit`
/// delivered mid-batch parks via [`PolicyState::request_commit`] and is
/// applied (and acknowledged) by the trailing
/// [`PolicyState::on_safe_point`] once `generating` drops. `Generate`
/// messages are parked on the backlog for the main loop.
fn drain_mailbox(
    rx: &Receiver<ToActor>,
    state: &mut PolicyState,
    backlog: &mut VecDeque<GenJob>,
    actor: u32,
    tx: &Sender<FromActor>,
    forwards: &[Sender<ToActor>],
    t0: Instant,
) -> Result<(), String> {
    loop {
        match rx.try_recv() {
            Ok(ToActor::Segment(seg)) => {
                forward_segment(forwards, &seg);
                state
                    .on_segment(seg)
                    .map_err(|e| format!("actor {actor} staging: {e}"))?;
            }
            Ok(ToActor::Commit(v)) => {
                commit_and_ack(state, actor, v, tx, t0)?;
            }
            Ok(ToActor::Generate(job)) => backlog.push_back(job),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
        }
    }
    service_safe_point(state, actor, tx, t0)
}

/// Deliver `Commit(v)`: apply immediately at a safe point, or park it
/// mid-generation-batch (`Deferred`) — the ack then rides the apply in
/// [`service_safe_point`]. Never applies under `generating == true`.
fn commit_and_ack(
    state: &mut PolicyState,
    actor: u32,
    version: u64,
    tx: &Sender<FromActor>,
    t0: Instant,
) -> Result<(), String> {
    let start_s = t0.elapsed().as_secs_f64();
    match state.request_commit(version) {
        CommitResult::Applied => ack_commit(state, actor, version, tx, t0, start_s),
        CommitResult::Deferred => Ok(()),
        other => Err(format!("actor {actor} commit v{version} failed: {other:?}")),
    }
}

/// Apply (and acknowledge) any commit parked while a batch was generating.
/// No-op when nothing is pending or we are not at a safe point.
fn service_safe_point(
    state: &mut PolicyState,
    actor: u32,
    tx: &Sender<FromActor>,
    t0: Instant,
) -> Result<(), String> {
    let start_s = t0.elapsed().as_secs_f64();
    match state.on_safe_point() {
        None => Ok(()),
        Some((v, CommitResult::Applied)) => ack_commit(state, actor, v, tx, t0, start_s),
        Some((v, other)) => Err(format!("actor {actor} deferred commit v{v} failed: {other:?}")),
    }
}

/// Send the Committed acknowledgement carrying the bit-exactness witness.
fn ack_commit(
    state: &PolicyState,
    actor: u32,
    version: u64,
    tx: &Sender<FromActor>,
    t0: Instant,
    start_s: f64,
) -> Result<(), String> {
    let reply = FromActor::Committed {
        actor,
        version,
        checksum: policy_checksum(state.params()),
        start_s,
        end_s: t0.elapsed().as_secs_f64(),
    };
    tx.send(reply).map_err(|_| "hub exited".to_string())
}

/// One actor worker: owns its [`PolicyState`], processes the command
/// mailbox, and generates rollouts while staging deltas that arrive
/// mid-generation at inter-batch safe points.
///
/// A panic inside the worker must not strand the hub: with several
/// workers alive the reply channel never disconnects, so an unwinding
/// thread that sent nothing would leave `collect_step` blocked forever.
/// The drop guard converts the unwind into a `Failed` reply.
fn actor_worker<C: Compute>(
    comp: &C,
    cfg: &LocalRunConfig,
    actor: u32,
    mut state: PolicyState,
    rx: Receiver<ToActor>,
    tx: Sender<FromActor>,
    forwards: Vec<Sender<ToActor>>,
    t0: Instant,
) {
    struct PanicGuard<'a> {
        actor: u32,
        tx: &'a Sender<FromActor>,
    }
    impl Drop for PanicGuard<'_> {
        fn drop(&mut self) {
            if std::thread::panicking() {
                let _ = self.tx.send(FromActor::Failed {
                    actor: self.actor,
                    msg: format!("actor {} worker panicked", self.actor),
                });
            }
        }
    }
    let _guard = PanicGuard { actor, tx: &tx };
    let mut backlog: VecDeque<GenJob> = VecDeque::new();
    loop {
        let msg = match backlog.pop_front() {
            Some(job) => ToActor::Generate(job),
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => return, // hub dropped the mailbox: shut down
            },
        };
        let outcome: Result<(), String> = match msg {
            ToActor::Generate(job) => {
                let start_s = t0.elapsed().as_secs_f64();
                run_gen_job(comp, cfg, &mut state, actor, &job, |st| {
                    drain_mailbox(&rx, st, &mut backlog, actor, &tx, &forwards, t0)
                })
                .and_then(|(rollouts, gen_tokens)| {
                    let reply = FromActor::Generated {
                        actor,
                        step: job.step,
                        rollouts,
                        gen_tokens,
                        start_s,
                        end_s: t0.elapsed().as_secs_f64(),
                    };
                    tx.send(reply).map_err(|_| "hub exited".to_string())
                })
            }
            ToActor::Segment(seg) => {
                forward_segment(&forwards, &seg);
                state
                    .on_segment(seg)
                    .map(|_| ())
                    .map_err(|e| format!("actor {actor} staging: {e}"))
                    // A commit that overtook these segments (relay routing
                    // reorders hub→actor message paths) lands as soon as
                    // staging completes.
                    .and_then(|()| service_safe_point(&mut state, actor, &tx, t0))
            }
            ToActor::Commit(v) => commit_and_ack(&mut state, actor, v, &tx, t0),
        };
        if let Err(msg) = outcome {
            let _ = tx.send(FromActor::Failed { actor, msg });
            return;
        }
    }
}

/// Pipelined executor: spawn workers, then per step dispatch generation,
/// train + stream the previous version concurrently, and collect
/// generation results and commit acknowledgements.
fn run_pipelined<C: Compute>(hub: &mut Hub<C>) -> Result<()> {
    let n = hub.cfg.n_actors;
    let comp = hub.comp;
    let cfg = hub.cfg;
    let t0 = hub.t0;
    let spec = cfg.distribution.clone().unwrap_or_default();
    std::thread::scope(|scope| {
        let (from_tx, from_rx) = channel::<FromActor>();
        // Create every mailbox first: relay workers need their peers'
        // senders at spawn time.
        let mut rxs: Vec<Option<Receiver<ToActor>>> = Vec::with_capacity(n);
        let mut to_txs: Vec<Sender<ToActor>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<ToActor>();
            to_txs.push(tx);
            rxs.push(Some(rx));
        }
        for (i, slot) in rxs.iter_mut().enumerate() {
            let rx = slot.take().expect("receiver consumed once");
            let state = PolicyState::new(hub.layout.clone(), hub.policy.clone(), 0);
            let ftx = from_tx.clone();
            let forwards: Vec<Sender<ToActor>> = spec
                .forward_targets(i)
                .into_iter()
                .map(|j| to_txs[j].clone())
                .collect();
            scope.spawn(move || actor_worker(comp, cfg, i as u32, state, rx, ftx, forwards, t0));
        }
        drop(from_tx);
        pipelined_hub_loop(hub, &to_txs, &from_rx)
        // `to_txs` drops here: workers see the disconnect and exit; the
        // scope joins them on the way out.
    })
}

/// Stream one version's delta into the distribution tree + commit to
/// every mailbox, moving (not cloning) each segment into its last target.
/// Flat topology: every actor gets every segment from the hub. Regional
/// topology ([`DistributionSpec`]): the hub sends each segment once per
/// region — to the relay — and relays forward to their peers, so the
/// hub-side send fan-out is O(regions) exactly like the WAN tree.
fn broadcast_and_commit<C: Compute>(
    hub: &mut Hub<C>,
    to_txs: &[Sender<ToActor>],
    batch_step: u64,
    batch: &[Rollout],
) -> Result<()> {
    let targets: Vec<usize> = match &hub.cfg.distribution {
        Some(spec) if !spec.is_flat() => spec.relays(),
        _ => (0..to_txs.len()).collect(),
    };
    let last = targets.len() - 1;
    hub.train_and_stream(batch_step, batch, |seg| {
        for &i in &targets[..last] {
            let _ = to_txs[i].send(ToActor::Segment(seg.clone()));
        }
        let _ = to_txs[targets[last]].send(ToActor::Segment(seg));
    })?;
    let v = hub.version;
    for (i, tx) in to_txs.iter().enumerate() {
        hub.sched.note_staged(i as u32, v);
        let _ = tx.send(ToActor::Commit(v));
    }
    Ok(())
}

fn pipelined_hub_loop<C: Compute>(
    hub: &mut Hub<C>,
    to_txs: &[Sender<ToActor>],
    from_rx: &Receiver<FromActor>,
) -> Result<()> {
    let n = to_txs.len();
    let mut last_batch: Option<(u64, Vec<Rollout>)> = None;
    for step in 0..hub.cfg.steps {
        // 1. Dispatch this step's generation on the stale policy.
        let jobs = hub.plan_step(step)?;
        for (asg, job) in &jobs {
            to_txs[asg.actor as usize]
                .send(ToActor::Generate(job.clone()))
                .map_err(|_| anyhow!("actor {} worker exited", asg.actor))?;
        }
        // 2. Train on the previous batch + stream D_{v} mid-generation.
        let committing = if let Some((prev_step, prev)) = last_batch.take() {
            broadcast_and_commit(hub, to_txs, prev_step, &prev)?;
            Some(hub.version)
        } else {
            None
        };
        // 3. Collect generation results and commit acknowledgements.
        let (results, spans) = collect_step(hub, from_rx, step, &jobs, committing, n)?;
        // 4. Deterministic batch assembly + ledger/scheduler bookkeeping,
        //    in assignment order.
        let mut batch: Vec<Rollout> = Vec::new();
        let mut results = results;
        let mut phase = (f64::INFINITY, 0.0f64);
        for (asg, job) in &jobs {
            let (mut rollouts, tokens, start_s, end_s) =
                results.remove(&asg.actor).expect("collected above");
            hub.timeline
                .record(&format!("actor{}", asg.actor), SpanKind::Rollout, start_s, end_s, step);
            hub.submit_and_settle(asg.actor, job, &mut rollouts, tokens, end_s - start_s)?;
            phase = (phase.0.min(start_s), phase.1.max(end_s));
            batch.extend(rollouts);
        }
        for (actor, c0, c1) in spans {
            hub.timeline.record(&format!("actor{actor}"), SpanKind::Commit, c0, c1, step);
        }
        hub.finish_generation(step, &batch, (phase.1 - phase.0).max(0.0) * 1e3);
        last_batch = Some((step, batch));
    }
    // Epilogue: train + commit the final version (no generation to hide
    // behind — the same tail the sequential executor pays every step).
    if let Some((prev_step, prev)) = last_batch.take() {
        broadcast_and_commit(hub, to_txs, prev_step, &prev)?;
        let (final_step, final_version) = (hub.cfg.steps, hub.version);
        let empty: Vec<(Assignment, GenJob)> = Vec::new();
        let (_, spans) = collect_step(hub, from_rx, final_step, &empty, Some(final_version), n)?;
        for (actor, c0, c1) in spans {
            hub.timeline
                .record(&format!("actor{actor}"), SpanKind::Commit, c0, c1, prev_step);
        }
    }
    Ok(())
}

type GenResults = BTreeMap<u32, (Vec<Rollout>, u64, f64, f64)>;

/// Block until every assigned actor returned its batch for `step` and —
/// when `committing` — every actor acknowledged the commit with a
/// checksum matching the trainer policy.
fn collect_step<C: Compute>(
    hub: &mut Hub<C>,
    from_rx: &Receiver<FromActor>,
    step: u64,
    jobs: &[(Assignment, GenJob)],
    committing: Option<u64>,
    n: usize,
) -> Result<(GenResults, Vec<(u32, f64, f64)>)> {
    let mut want_gen: BTreeSet<u32> = jobs.iter().map(|(a, _)| a.actor).collect();
    let mut want_commit: BTreeSet<u32> = match committing {
        Some(_) => (0..n as u32).collect(),
        None => BTreeSet::new(),
    };
    let mut results: GenResults = BTreeMap::new();
    let mut commit_spans: Vec<(u32, f64, f64)> = Vec::new();
    while !want_gen.is_empty() || !want_commit.is_empty() {
        match from_rx.recv() {
            Ok(FromActor::Generated { actor, step: s, rollouts, gen_tokens, start_s, end_s }) => {
                if s != step {
                    bail!("actor {actor} returned batch for step {s} during step {step}");
                }
                if !want_gen.remove(&actor) {
                    bail!("unexpected generation result from actor {actor}");
                }
                results.insert(actor, (rollouts, gen_tokens, start_s, end_s));
            }
            Ok(FromActor::Committed { actor, version, checksum, start_s, end_s }) => {
                let Some(v) = committing else {
                    bail!("unexpected commit ack v{version} from actor {actor}");
                };
                if version != v {
                    bail!("actor {actor} committed v{version}, expected v{v}");
                }
                // Cross-thread bit-exactness at every committed version.
                if checksum != hub.accum[(v - 1) as usize].policy_checksum {
                    bail!("actor {actor} diverged from trainer policy at v{version}");
                }
                if !want_commit.remove(&actor) {
                    bail!("duplicate commit ack from actor {actor}");
                }
                hub.sched.note_committed(actor, version);
                commit_spans.push((actor, start_s, end_s));
            }
            Ok(FromActor::Failed { msg, .. }) => bail!("{msg}"),
            Err(_) => bail!("actor workers exited before step {step} completed"),
        }
    }
    Ok((results, commit_spans))
}
