//! In-process SparrowRL runtime: the paper's full loop on real compute.
//!
//! Per step: the Job Ledger issues prompts under leases; actors generate
//! rollout groups through the PJRT policy artifact (Pallas attention);
//! rewards + GRPO/RLOO/OPO advantages feed the train-step artifact; the
//! new bf16 policy is diffed into a sealed delta checkpoint, segmented,
//! streamed to every actor's staging buffer, and committed at a safe
//! point. An optional SFT warmup phase reuses the same train-step artifact
//! with advantage 1 and gold completions.
//!
//! Everything the distributed runtime does happens here except sockets —
//! transfer runs through the same segment/reassembly/staging code paths,
//! so bit-exactness of actor policies is asserted against the trainer's.

use crate::actor::rollout::{generate_batch, SampleCfg};
use crate::actor::{CommitResult, PolicyState};
use crate::data::{pack_batch, Benchmark, Task};
use crate::delta::{CheckpointStore, ParamSet};
use crate::ledger::{JobLedger, LeasePolicy};
use crate::runtime::{Engines, TrainState};
use crate::scheduler::{Scheduler, SchedulerConfig, VersionState};
use crate::trainer::{group_advantages, Algorithm, Rollout};
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Configuration for a local end-to-end run.
#[derive(Clone, Debug)]
pub struct LocalRunConfig {
    pub model: String,
    pub algorithm: Algorithm,
    pub bench: Benchmark,
    pub n_actors: usize,
    /// Rollout group size per prompt (GRPO's G).
    pub group_size: usize,
    /// RL steps to run.
    pub steps: u64,
    /// Supervised warmup steps before RL (teaches the task format).
    pub sft_steps: u64,
    pub lr_sft: f32,
    pub lr_rl: f32,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub segment_bytes: usize,
    pub seed: u64,
    /// Print per-step progress lines.
    pub verbose: bool,
}

impl LocalRunConfig {
    pub fn quick(model: &str) -> LocalRunConfig {
        LocalRunConfig {
            model: model.to_string(),
            algorithm: Algorithm::Grpo,
            bench: Benchmark::Gsm8k,
            n_actors: 2,
            group_size: 4,
            steps: 5,
            sft_steps: 30,
            lr_sft: 5e-3,
            lr_rl: 1e-6,
            max_new_tokens: 8,
            temperature: 0.8,
            segment_bytes: 16 << 10,
            seed: 0,
            verbose: false,
        }
    }
}

/// Per-RL-step record (feeds Figure 4 and EXPERIMENTS.md).
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u64,
    pub loss: f32,
    pub mean_reward: f32,
    /// Measured nonzero update ratio of the bf16 policy.
    pub rho: f64,
    pub payload_bytes: u64,
    pub dense_bytes: u64,
    pub gen_tokens: u64,
    pub extract_ms: f64,
    pub train_ms: f64,
    pub rollout_ms: f64,
}

/// Result of a local run.
pub struct RunReport {
    pub sft_losses: Vec<f32>,
    pub steps: Vec<StepLog>,
    pub final_version: u64,
    pub wall_s: f64,
}

impl RunReport {
    pub fn mean_rho(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.rho).sum::<f64>() / self.steps.len() as f64
    }

    pub fn mean_reward_last_quarter(&self) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return 0.0;
        }
        let tail = &self.steps[n - (n / 4).max(1)..];
        tail.iter().map(|s| s.mean_reward).sum::<f32>() / tail.len() as f32
    }
}

/// Run the full loop. See module docs.
pub fn run_local(cfg: &LocalRunConfig) -> Result<RunReport> {
    let wall0 = Instant::now();
    let spec = crate::config::model(&cfg.model)
        .with_context(|| format!("unknown model {}", cfg.model))?;
    if !spec.runnable {
        bail!("{} is analytic-only; pick a sparrow-* model", cfg.model);
    }
    let eng = Engines::load(&crate::runtime::artifacts_dir(), &cfg.model)?;
    let mut rng = Rng::new(cfg.seed);
    let mut state = TrainState::init(&spec.layout, &mut rng);
    let b_train = eng.manifest.b_train;
    let b_gen = eng.manifest.b_gen;
    let t = eng.manifest.max_seq;
    if cfg.group_size > b_gen {
        bail!("group_size {} exceeds artifact b_gen {}", cfg.group_size, b_gen);
    }

    // ---------------- SFT warmup: same artifact, adv = 1 ----------------
    let mut sft_losses = Vec::new();
    let mut task_counter: u64 = 0;
    for _ in 0..cfg.sft_steps {
        let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..b_train)
            .map(|_| {
                task_counter += 1;
                let task = Task::from_prompt_id(task_counter, cfg.bench);
                (task.prompt_tokens(), task.answer_tokens())
            })
            .collect();
        let batch = pack_batch(&pairs, b_train, t);
        let adv = vec![1.0f32; b_train];
        let loss = eng.train_step(&mut state, &batch.tokens, &batch.gen_mask, &adv, cfg.lr_sft)?;
        sft_losses.push(loss);
    }

    // ---------------- RL phase ------------------------------------------
    let mut version: u64 = 0;
    let mut policy = state.to_policy();
    let mut store = CheckpointStore::in_memory();
    let mut ledger = JobLedger::new(LeasePolicy::default());
    let mut sched = Scheduler::new(SchedulerConfig::default());
    let mut actors: Vec<PolicyState> = (0..cfg.n_actors)
        .map(|_| PolicyState::new(spec.layout.clone(), policy.clone(), 0))
        .collect();
    for i in 0..cfg.n_actors {
        sched.register(i as u32, 1000.0);
        sched.observe_version(i as u32, VersionState { active: 0, staged: None });
    }
    // Version-0 "hash": the genesis policy has no checkpoint; use zeros.
    let mut version_hash = [0u8; 32];
    let prompts_per_step = b_train / cfg.group_size;
    let mut steps = Vec::new();
    let mut clock = 0.0f64; // logical seconds for lease bookkeeping

    for step in 0..cfg.steps {
        // -- issue prompts under leases --------------------------------
        let prompt_ids: Vec<u64> = (0..prompts_per_step)
            .map(|_| {
                task_counter += 1;
                task_counter
            })
            .collect();
        ledger.post(prompt_ids.iter().copied());
        let assignments = sched.allocate(version, prompts_per_step as u64);
        if assignments.is_empty() {
            bail!("no eligible actors at step {step}");
        }

        // -- rollout generation (real PJRT) ----------------------------
        let t_roll = Instant::now();
        let mut rollouts: Vec<Rollout> = Vec::new();
        let mut gen_tokens = 0u64;
        for asg in &assignments {
            let actor = asg.actor as usize;
            let claimed = ledger.issue(asg.actor, version, version_hash, clock, asg.requests as usize);
            let policy_ref = actors[actor].params().clone();
            actors[actor].set_generating(true);
            for chunk in claimed.chunks(b_gen / cfg.group_size) {
                // One generation batch holds group_size samples per prompt.
                let mut prompts = Vec::new();
                for &pid in chunk {
                    let task = Task::from_prompt_id(pid, cfg.bench);
                    for _ in 0..cfg.group_size {
                        prompts.push(task.prompt_tokens());
                    }
                }
                let gens = generate_batch(
                    &eng,
                    &policy_ref,
                    &prompts,
                    SampleCfg {
                        temperature: cfg.temperature,
                        max_new_tokens: cfg.max_new_tokens,
                    },
                    &mut rng,
                )?;
                for (gi, g) in gens.iter().enumerate() {
                    let pid = chunk[gi / cfg.group_size];
                    let task = Task::from_prompt_id(pid, cfg.bench);
                    let completion = &g.tokens[g.prompt_len..];
                    gen_tokens += completion.len() as u64;
                    rollouts.push(Rollout {
                        prompt_id: pid,
                        actor: asg.actor,
                        version,
                        prompt_tokens: g.tokens[..g.prompt_len].to_vec(),
                        generated_tokens: completion.to_vec(),
                        reward: task.reward(completion),
                    });
                }
            }
            actors[actor].set_generating(false);
            clock += 1.0;
            // Submit under the acceptance predicate.
            for &pid in &claimed {
                ledger
                    .submit(asg.actor, pid, version, version_hash, clock)
                    .map_err(|e| anyhow::anyhow!("ledger rejected {pid}: {e:?}"))?;
            }
            sched.settle(asg.actor, gen_tokens, t_roll.elapsed().as_secs_f64().max(1e-3));
        }
        let rollout_ms = t_roll.elapsed().as_secs_f64() * 1e3;
        let mean_reward =
            rollouts.iter().map(|r| r.reward).sum::<f32>() / rollouts.len().max(1) as f32;

        // -- advantages + train step ------------------------------------
        let adv = group_advantages(&rollouts, cfg.algorithm);
        let pairs: Vec<(Vec<i32>, Vec<i32>)> = rollouts
            .iter()
            .map(|r| (r.prompt_tokens.clone(), r.generated_tokens.clone()))
            .collect();
        let batch = pack_batch(&pairs, b_train, t);
        let mut adv_padded = vec![0.0f32; b_train];
        adv_padded[..adv.len()].copy_from_slice(&adv);
        let t_train = Instant::now();
        let loss = eng.train_step(&mut state, &batch.tokens, &batch.gen_mask, &adv_padded, cfg.lr_rl)?;
        let train_ms = t_train.elapsed().as_secs_f64() * 1e3;

        // -- fused delta extraction + encode + segment + stream ----------
        // One pass: segments hit every actor's staging decoder while later
        // tensors are still being scanned (paper §5.2 pipelining). The
        // sealed artifact for the store is assembled from the same bytes.
        let t_extract = Instant::now();
        let new_policy = state.to_policy();
        let mut stream_err: Option<String> = None;
        let (ckpt, stream_stats) = crate::trainer::stream_checkpoint(
            &spec.layout,
            &policy,
            &new_policy,
            version,
            version + 1,
            cfg.segment_bytes,
            |seg| {
                for (i, actor) in actors.iter_mut().enumerate() {
                    if let Err(e) = actor.on_segment(seg.clone()) {
                        stream_err.get_or_insert(format!("actor {i} staging: {e}"));
                    }
                }
            },
        );
        if let Some(e) = stream_err {
            bail!("{e}");
        }
        let extract_ms = t_extract.elapsed().as_secs_f64() * 1e3;
        let rho = stream_stats.nnz as f64 / spec.total_params() as f64;
        let payload = ckpt.payload_bytes();
        store.put(ckpt.clone())?;

        // -- commit at the safe point ------------------------------------
        commit_all(&mut actors, ckpt.version)?;
        version += 1;
        version_hash = ckpt.hash;
        policy = new_policy;
        for (i, a) in actors.iter().enumerate() {
            // Bit-exactness: every actor's policy equals the trainer's.
            if a.params() != &policy {
                bail!("actor {i} diverged from trainer policy at v{version}");
            }
            sched.observe_version(i as u32, VersionState { active: version, staged: None });
        }

        let log = StepLog {
            step,
            loss,
            mean_reward,
            rho,
            payload_bytes: payload,
            dense_bytes: spec.dense_bytes_bf16(),
            gen_tokens,
            extract_ms,
            train_ms,
            rollout_ms,
        };
        if cfg.verbose {
            println!(
                "step {:>3}  loss {:>8.4}  reward {:>5.3}  rho {:>7.4}%  payload {:>10}  ({}x smaller)  gen {:>5} tok",
                step,
                loss,
                mean_reward,
                rho * 100.0,
                crate::util::fmt_bytes(payload),
                (spec.dense_bytes_bf16() / payload.max(1)),
                gen_tokens,
            );
        }
        steps.push(log);
    }

    Ok(RunReport {
        sft_losses,
        steps,
        final_version: version,
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

/// Commit a fully staged version on every actor at the safe point.
fn commit_all(actors: &mut [PolicyState], version: u64) -> Result<()> {
    for (i, actor) in actors.iter_mut().enumerate() {
        match actor.commit(version) {
            CommitResult::Applied => {}
            other => bail!("actor {i} commit failed: {other:?}"),
        }
    }
    Ok(())
}

/// Evaluate greedy accuracy of the current trainer policy on `n` fresh
/// tasks (reward == 1 exact matches).
pub fn evaluate(
    eng: &Engines,
    policy: &ParamSet,
    bench: Benchmark,
    n: usize,
    max_new: usize,
    seed: u64,
) -> Result<f32> {
    let mut rng = Rng::new(seed);
    let b_gen = eng.manifest.b_gen;
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut id = 1_000_000u64;
    while total < n {
        let tasks: Vec<Task> = (0..b_gen.min(n - total))
            .map(|_| {
                id += 1;
                Task::from_prompt_id(id, bench)
            })
            .collect();
        let prompts: Vec<Vec<i32>> = tasks.iter().map(|t| t.prompt_tokens()).collect();
        let gens = generate_batch(
            eng,
            policy,
            &prompts,
            SampleCfg { temperature: 0.0, max_new_tokens: max_new },
            &mut rng,
        )?;
        for (task, g) in tasks.iter().zip(&gens) {
            if task.reward(&g.tokens[g.prompt_len..]) == 1.0 {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f32 / total.max(1) as f32)
}
