//! In-process SparrowRL runtime: the paper's full loop on real compute.
//!
//! Per step: the Job Ledger issues prompts under real-clock leases; actors
//! generate rollout groups through the PJRT policy artifact (Pallas
//! attention); rewards + GRPO/RLOO/OPO advantages feed the train-step
//! artifact; the new bf16 policy is diffed into a sealed delta checkpoint,
//! segmented, streamed to every actor's staging decoder, and committed at
//! a safe point. An optional SFT warmup phase reuses the same train-step
//! artifact with advantage 1 and gold completions.
//!
//! The loop itself lives in [`crate::rt::pipeline`] and runs under either
//! executor: [`ExecMode::Sequential`] (phase-sequential reference) or
//! [`ExecMode::Pipelined`] (generation overlaps training + delta
//! streaming, the paper's §2.1/Fig 7 schedule). Everything the distributed
//! runtime does happens here except sockets — transfer runs through the
//! same segment/reassembly/staging code paths, so bit-exactness of actor
//! policies is asserted against the trainer's in both modes.

use crate::actor::rollout::SampleCfg;
use crate::data::{Benchmark, Task};
use crate::delta::ParamSet;
use crate::ledger::LeasePolicy;
use crate::metrics::Timeline;
use crate::rt::compute::Compute;
use crate::rt::pipeline::ExecMode;
use crate::runtime::Engines;
use crate::trainer::Algorithm;
use crate::transport::api::SimNetConfig;
use crate::transport::tcp::TcpConfig;
use crate::util::Rng;
use anyhow::{bail, Context, Result};

/// Which `transport::api` backend carries hub↔actor traffic in the
/// pipelined executor. All three run the identical executor and worker
/// code; in deterministic mode they commit bit-identical policies.
#[derive(Clone, Debug, Default)]
pub enum TransportKind {
    /// In-process mpsc mailboxes, zero-copy message passing (optionally
    /// relay-routed per `LocalRunConfig::distribution`).
    #[default]
    InProc,
    /// In-process workers behind the netsim WAN model: delta streams
    /// arrive in `deliver_striped` order per region relay leg.
    Sim(SimNetConfig),
    /// Real loopback sockets: framed `Msg` traffic, striped segment
    /// push, throttled writers, real crash/partition failure surfaces.
    Tcp(TcpConfig),
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Sim(_) => "sim",
            TransportKind::Tcp(_) => "tcp",
        }
    }
}

/// Why an actor was removed from the fleet mid-run. Carried on
/// `session::Event::Failover` so downstream consumers never have to
/// parse ad-hoc reason strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// Transport reported the worker dead (process exit, socket slam).
    Crash,
    /// Leases expired while the actor stayed silent.
    Stall,
    /// Commit-barrier acknowledgement timed out — reachable but mute.
    Partition,
    /// Spot preemption: the actor sent its `Draining` warning before the
    /// provider reclaimed it.
    Preempted,
    /// A region relay died, taking its downstream peers with it.
    RelayLost,
    /// Graceful departure that could not finish draining in time and was
    /// escalated to failover.
    Left,
}

impl std::fmt::Display for FailReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FailReason::Crash => "crash",
            FailReason::Stall => "stall",
            FailReason::Partition => "partition",
            FailReason::Preempted => "preempted",
            FailReason::RelayLost => "relay-lost",
            FailReason::Left => "left",
        })
    }
}

/// How a joining actor is brought to the hub's active policy version.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BootstrapKind {
    /// Replay the stored sparse deltas `D_{1}..D_{v}` through the
    /// joiner's staging decoder — O(rho * k) bytes on the wire.
    DeltaChain,
    /// Ship the full dense bf16 policy — O(N) bytes; the fallback when
    /// no delta chain is available.
    Snapshot,
}

impl BootstrapKind {
    pub fn name(&self) -> &'static str {
        match self {
            BootstrapKind::DeltaChain => "delta-chain",
            BootstrapKind::Snapshot => "snapshot",
        }
    }
}

/// A scripted membership join: at the boundary after version
/// `at_version` commits, the hub invites the (so far dormant) worker
/// `actor`, bootstraps it via `bootstrap`, and admits it to the
/// scheduler and bandwidth gate.
#[derive(Clone, Copy, Debug)]
pub struct JoinSpec {
    pub actor: u32,
    pub at_version: u64,
    pub bootstrap: BootstrapKind,
}

/// A scripted graceful leave: at the boundary after version
/// `at_version` commits, the hub stops scheduling `actor`, lets its
/// outstanding work finish (or hands leased prompts back), then
/// releases it with a `Drain` message.
#[derive(Clone, Copy, Debug)]
pub struct LeaveSpec {
    pub actor: u32,
    pub at_version: u64,
}

/// Elastic-membership script for a run: which actors join late, which
/// leave gracefully, and whether the cost-model autoscaler emits
/// scale decisions at step boundaries. Preemptions are scripted on the
/// transport side (`tcp::KillSpec` with `KillMode::Preempt`).
#[derive(Clone, Debug, Default)]
pub struct ElasticSpec {
    pub joins: Vec<JoinSpec>,
    pub leaves: Vec<LeaveSpec>,
    /// Evaluate `cost::Autoscaler` each step and emit
    /// `Event::Autoscale` decisions (advisory — decisions are logged,
    /// not auto-applied; the fleet follows the explicit script).
    pub autoscale: bool,
}

impl ElasticSpec {
    pub fn is_empty(&self) -> bool {
        self.joins.is_empty() && self.leaves.is_empty() && !self.autoscale
    }
}

/// A scripted hot-swap: in the run epilogue (after the final training
/// commit, with every actor idle at the final version boundary), the hub
/// retargets `actor` onto the published fine-tune `model@version` by
/// shipping the composed registry swap delta through the ordinary
/// Seg/Commit staging machinery. The actor's post-swap checksum must
/// equal the registry's published witness for `model@version` — the same
/// bit-exactness bar a fresh bootstrap of that model meets.
#[derive(Clone, Debug)]
pub struct SwapSpec {
    pub actor: u32,
    pub model: String,
    pub version: u64,
}

/// Configuration for a local end-to-end run.
#[derive(Clone, Debug)]
pub struct LocalRunConfig {
    pub model: String,
    pub algorithm: Algorithm,
    pub bench: Benchmark,
    pub n_actors: usize,
    /// Rollout group size per prompt (GRPO's G).
    pub group_size: usize,
    /// RL steps to run.
    pub steps: u64,
    /// Supervised warmup steps before RL (teaches the task format).
    pub sft_steps: u64,
    pub lr_sft: f32,
    pub lr_rl: f32,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub segment_bytes: usize,
    pub seed: u64,
    /// Print per-step progress lines.
    pub verbose: bool,
    /// Replace wall-clock lease/settlement time with deterministic virtual
    /// time so a seed fully determines the run — the sequential and
    /// pipelined executors then produce bit-identical results (used by the
    /// equivalence tests; leave off for real throughput measurements).
    pub deterministic: bool,
    /// Geo-distribution wiring for the pipelined executor: actors grouped
    /// into regions with one relay each; the hub streams delta segments to
    /// relays only and relays forward to peers (the in-process mirror of
    /// `transport::DistributionPlan`). `None` = flat hub→all streaming.
    /// The sequential reference executor ignores this — staging is
    /// order-insensitive, so results are bit-identical either way.
    pub distribution: Option<crate::rt::pipeline::DistributionSpec>,
    /// Communication backend for the pipelined executor (the sequential
    /// reference has no transport; it ignores this).
    pub transport: TransportKind,
    /// Job-ledger lease policy (fault tests shorten `min_s` so expiry
    /// fires within a test's runtime).
    pub lease: LeasePolicy,
    /// Lease against the wall clock even when `deterministic` is set:
    /// generation stays bit-reproducible (virtual settle durations keep
    /// the scheduler deterministic) while stalled/partitioned actors
    /// genuinely time out — the fault-tolerance tests' configuration.
    pub wall_leases: bool,
    /// Elastic-membership script: scripted joins/leaves plus the
    /// autoscaler toggle. Empty (the default) = fixed fleet, exactly
    /// the pre-elastic behaviour. Pipelined executor only; requires
    /// flat distribution and the InProc or Tcp backend.
    pub elastic: ElasticSpec,
    /// Root of the content-addressed durable store
    /// ([`crate::delta::DurableStore`]). `Some` makes every commit
    /// crash-durable (objects + journal record) before it is observable;
    /// `None` (the default) keeps the run fully in memory.
    pub persist_dir: Option<std::path::PathBuf>,
    /// Continue the durable run found under `persist_dir` from its last
    /// journaled version instead of starting fresh. Requires
    /// `deterministic` (without `wall_leases`) and an empty elastic
    /// script; the resumed run's committed-checksum trace is bitwise
    /// identical to an uninterrupted run's.
    pub resume: bool,
    /// Root of a [`crate::delta::ModelRegistry`] this run reads published
    /// fine-tunes from (hot-swaps) and/or publishes into. Required when
    /// `swaps` is non-empty.
    pub registry_dir: Option<std::path::PathBuf>,
    /// Scripted epilogue hot-swaps ([`SwapSpec`]), at most one per actor.
    pub swaps: Vec<SwapSpec>,
    /// Publish the finished run's folded chain into `registry_dir` under
    /// this model name (requires `persist_dir` — publishing folds the
    /// durable journal, not in-memory state).
    pub publish: Option<String>,
}

impl LocalRunConfig {
    pub fn quick(model: &str) -> LocalRunConfig {
        LocalRunConfig {
            model: model.to_string(),
            algorithm: Algorithm::Grpo,
            bench: Benchmark::Gsm8k,
            n_actors: 2,
            group_size: 4,
            steps: 5,
            sft_steps: 30,
            lr_sft: 5e-3,
            lr_rl: 1e-6,
            max_new_tokens: 8,
            temperature: 0.8,
            segment_bytes: 16 << 10,
            seed: 0,
            verbose: false,
            deterministic: false,
            distribution: None,
            transport: TransportKind::InProc,
            lease: LeasePolicy::default(),
            wall_leases: false,
            elastic: ElasticSpec::default(),
            persist_dir: None,
            resume: false,
            registry_dir: None,
            swaps: Vec::new(),
            publish: None,
        }
    }
}

/// Per-RL-step record (feeds Figure 4, EXPERIMENTS.md, and the Session
/// API's `Event::StepCompleted`).
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u64,
    pub loss: f32,
    pub mean_reward: f32,
    /// Measured nonzero update ratio of the bf16 policy.
    pub rho: f64,
    pub payload_bytes: u64,
    pub dense_bytes: u64,
    pub gen_tokens: u64,
    pub extract_ms: f64,
    pub train_ms: f64,
    pub rollout_ms: f64,
    /// SHA-256 of the trainer policy committed by this step's train pass
    /// (every actor acknowledged the same digest — the bit-exactness
    /// witness, and the cross-executor equivalence probe).
    pub policy_checksum: [u8; 32],
}

impl StepLog {
    /// The committed policy's SHA-256 witness as lowercase hex — the
    /// cross-backend equivalence digest every surface prints.
    pub fn checksum_hex(&self) -> String {
        crate::util::hex(&self.policy_checksum)
    }

    /// The canonical one-line progress rendering (the CLI's per-step
    /// line and the runtime's `verbose` knob print exactly this).
    pub fn progress_line(&self) -> String {
        format!(
            "step {:>3}  loss {:>8.4}  reward {:>5.3}  rho {:>7.4}%  payload {:>10}  ({}x smaller)  gen {:>5} tok",
            self.step,
            self.loss,
            self.mean_reward,
            self.rho * 100.0,
            crate::util::fmt_bytes(self.payload_bytes),
            self.dense_bytes / self.payload_bytes.max(1),
            self.gen_tokens,
        )
    }
}

/// Result of a local run. Assembled from the session event stream (see
/// `session::Event`), so report and events cannot disagree.
#[derive(Clone)]
pub struct RunReport {
    pub sft_losses: Vec<f32>,
    pub steps: Vec<StepLog>,
    pub final_version: u64,
    pub wall_s: f64,
    /// Measured execution spans (rollout/train/extract/transfer/commit)
    /// — the real-runtime counterpart of the simulator's Figure 9 trace;
    /// `timeline.overlap_ratio(..)` quantifies how much synchronization
    /// the pipelined executor hid inside the generation window.
    pub timeline: Timeline,
    /// Actors lost mid-run and absorbed via lease-driven failover
    /// (crash, stall, partition, un-warned preemption) — 0 on a
    /// healthy run. Graceful drains are counted in `drains`, not here.
    pub failovers: u64,
    /// Prompts re-leased to survivors after failures or drain
    /// handbacks, exactly once per event per prompt.
    pub requeued_prompts: u64,
    /// Actors admitted mid-run (invite → bootstrap → witness → lease).
    pub joins: u64,
    /// Actors that departed gracefully (scripted leave or clean Bye) —
    /// these do NOT inflate `failovers`.
    pub drains: u64,
    /// Spot preemptions whose warning reached the hub before the kill.
    pub preempts: u64,
    /// Actors retargeted onto a different published fine-tune in the run
    /// epilogue (registry hot-swap, witness-verified).
    pub swaps: u64,
}

impl RunReport {
    pub fn mean_rho(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|s| s.rho).sum::<f64>() / self.steps.len() as f64
    }

    pub fn mean_reward_last_quarter(&self) -> f32 {
        let n = self.steps.len();
        if n == 0 {
            return 0.0;
        }
        let tail = &self.steps[n - (n / 4).max(1)..];
        tail.iter().map(|s| s.mean_reward).sum::<f32>() / tail.len() as f32
    }
}

/// Run the full loop on PJRT artifacts with the chosen executor.
///
/// **Deprecated shim** (kept for one release): this is now a thin
/// blocking wrapper over [`crate::session::Session`] — it spawns a
/// session and immediately `join()`s it. New code should build a
/// [`crate::session::RunSpec`] and subscribe to the typed event stream.
pub fn run_local_mode(cfg: &LocalRunConfig, mode: ExecMode) -> Result<RunReport> {
    let spec = crate::config::model(&cfg.model)
        .with_context(|| format!("unknown model {}", cfg.model))?;
    if !spec.runnable {
        bail!("{} is analytic-only; pick a sparrow-* model", cfg.model);
    }
    let eng = Engines::load(&crate::runtime::artifacts_dir(), &cfg.model)?;
    crate::session::Session::spawn(cfg.clone(), spec.layout.clone(), eng, mode)?.join()
}

/// Run the full loop with the phase-sequential executor. See module docs.
///
/// **Deprecated shim** — see [`run_local_mode`].
pub fn run_local(cfg: &LocalRunConfig) -> Result<RunReport> {
    run_local_mode(cfg, ExecMode::Sequential)
}

/// Evaluate greedy accuracy of the current trainer policy on `n` fresh
/// tasks (reward == 1 exact matches). Works on any [`Compute`] backend
/// (PJRT [`Engines`] or [`crate::rt::SyntheticCompute`]).
pub fn evaluate<C: Compute>(
    comp: &C,
    policy: &ParamSet,
    bench: Benchmark,
    n: usize,
    max_new: usize,
    seed: u64,
) -> Result<f32> {
    let mut rng = Rng::new(seed);
    let b_gen = comp.shape().b_gen;
    // A zero generation batch would make the chunking loop below spin
    // forever claiming zero tasks per pass — reject it up front.
    if b_gen == 0 {
        bail!("compute backend reports b_gen == 0; cannot batch evaluation prompts");
    }
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut id = 1_000_000u64;
    while total < n {
        let tasks: Vec<Task> = (0..b_gen.min(n - total))
            .map(|_| {
                id += 1;
                Task::from_prompt_id(id, bench)
            })
            .collect();
        let prompts: Vec<Vec<i32>> = tasks.iter().map(|t| t.prompt_tokens()).collect();
        let gens = comp.generate(
            policy,
            &prompts,
            SampleCfg { temperature: 0.0, max_new_tokens: max_new },
            &mut rng,
        )?;
        for (task, g) in tasks.iter().zip(&gens) {
            if task.reward(&g.tokens[g.prompt_len..]) == 1.0 {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f32 / total.max(1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::ModelLayout;
    use crate::rt::SyntheticCompute;
    use crate::runtime::TrainState;

    fn policy() -> ParamSet {
        let layout = ModelLayout::transformer("eval-t", 64, 16, 2, 32);
        TrainState::init(&layout, &mut Rng::new(1)).to_policy()
    }

    #[test]
    fn evaluate_bails_on_zero_gen_batch_instead_of_spinning() {
        // Regression: b_gen == 0 used to make the chunking loop claim
        // zero tasks per pass and never terminate.
        let comp = SyntheticCompute::new(8, 0, 32);
        let err = evaluate(&comp, &policy(), Benchmark::Gsm8k, 4, 4, 0)
            .expect_err("b_gen == 0 must be rejected");
        assert!(format!("{err:#}").contains("b_gen"), "{err:#}");
    }

    #[test]
    fn evaluate_runs_on_synthetic_compute() {
        let comp = SyntheticCompute::new(8, 4, 32);
        // n > b_gen exercises the multi-batch path.
        let acc = evaluate(&comp, &policy(), Benchmark::Gsm8k, 6, 4, 0).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }
}
