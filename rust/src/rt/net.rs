//! The hub↔actor message vocabulary and its TCP framing: `Msg` is the
//! *entire* protocol every [`crate::transport::api::Transport`] backend
//! speaks (in-process channels pass it by value; the Tcp backend frames
//! it over loopback sockets with throttled writers emulating WAN
//! bandwidth — no root/tc required).
//!
//! The wire protocol is deliberately tiny — length-prefixed frames with a
//! one-byte tag — because the heavy lifting (segment framing, integrity,
//! reassembly, staging) is already done by `transport` and `actor`.
//! Decoding is hostile-input safe: truncated frames, unknown tags, and
//! oversized length prefixes are rejected without panicking and without
//! attacker-controlled allocation (counts are validated against the
//! actual body length before any `Vec` is reserved).

use crate::transport::Segment;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Hard cap on one frame's length prefix. Larger than any real message
/// (segments are ~1 MiB), small enough that a hostile prefix cannot ask
/// the reader to buffer unbounded memory.
pub const MAX_FRAME: usize = 256 << 20;

/// Control/data messages between Trainer Hub and Actors — the complete
/// transport vocabulary (membership, delta push, staged activation, job
/// dispatch, rollout results, shutdown).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Actor introduces itself (actor id, gpu-class prior tokens/s).
    Hello { actor: u32, prior_tau: f64 },
    /// One delta-checkpoint segment.
    Seg(Segment),
    /// Commit a fully staged version (§5.2 staged activation).
    Commit { version: u64 },
    /// Actor acknowledges activation of `version`. `hash` is the SHA-256
    /// of the actor's post-commit policy bits ([`policy_checksum`]) — the
    /// cross-process bit-exactness witness the hub checks against its own
    /// trainer policy before accepting any rollouts generated on it.
    ///
    /// [`policy_checksum`]: crate::rt::pipeline::policy_checksum
    Activated { actor: u32, version: u64, hash: [u8; 32] },
    /// Job: generate rollouts for `prompt_ids` on `version`, drawing
    /// randomness from `rng_seed`. The seed is hub-assigned (derived from
    /// the run seed and the *original* assignment) so a job re-issued to
    /// a survivor after a failure regenerates bit-identical rollouts.
    Job { version: u64, rng_seed: u64, prompt_ids: Vec<u64> },
    /// One rollout result. `hash` is the checkpoint hash of the actor's
    /// active version — the ledger's acceptance predicate (§5.4) checks
    /// it against the lease. `tokens` are the generated completion only
    /// (prompt tokens are re-derived from `prompt_id`).
    RolloutResult {
        actor: u32,
        prompt_id: u64,
        version: u64,
        hash: [u8; 32],
        reward: f32,
        tokens: Vec<i32>,
    },
    /// Orderly shutdown.
    Bye,
    /// A new actor announces itself to a running fleet (elastic
    /// membership): capability prior (`prior_tau`, tokens/s) and region
    /// tag for the bandwidth gate. The hub replies with a bootstrap —
    /// either the delta chain (`Seg`* then `Commit`) or a [`Msg::Snapshot`]
    /// — and admits the actor only after its `Activated` witness matches
    /// the trainer's policy checksum.
    Join { actor: u32, prior_tau: f64, region: u32 },
    /// Full-policy bootstrap: every bf16 parameter in layout order.
    /// `hash` is the checkpoint hash of `version` (what the ledger's
    /// acceptance predicate expects on rollouts generated against it).
    /// The fallback when the delta chain is unavailable — O(N) bytes
    /// where the chain costs O(rho * k).
    Snapshot { version: u64, hash: [u8; 32], data: Vec<u8> },
    /// Hub asks an actor to drain: it holds no leased work (the hub only
    /// sends this once the actor's slots are settled), so it replies
    /// `Bye` and exits without burning the failover path.
    Drain { grace_ms: u64 },
    /// Actor announces it is about to be lost (spot-preemption warning):
    /// the hub hands its leased prompts back to the pool without the
    /// expiry penalty and stops scheduling it; if the hard kill lands
    /// before the drain completes, remaining leases take the normal
    /// reissue path.
    Draining { actor: u32 },
    /// Hub provisions a dormant spare: the deterministic stand-in for
    /// "a new spot instance came up". The spare answers with `Join`.
    Invite { actor: u32 },
    /// Hot-swap annotation: the composed registry delta that follows (as
    /// ordinary `Seg`* + `Commit`) retargets this actor onto the
    /// published fine-tune `model@version` instead of advancing the
    /// current run's policy. Purely informational on the actor side —
    /// staging, integrity, and activation witness all ride the existing
    /// machinery; the hub checks the `Activated` hash against the
    /// registry's published witness for `model@version`.
    Swap { model: String, version: u64 },
}

const TAG_HELLO: u8 = 1;
const TAG_SEG: u8 = 2;
const TAG_COMMIT: u8 = 3;
const TAG_ACTIVATED: u8 = 4;
const TAG_JOB: u8 = 5;
const TAG_RESULT: u8 = 6;
const TAG_BYE: u8 = 7;
const TAG_JOIN: u8 = 8;
const TAG_SNAPSHOT: u8 = 9;
const TAG_DRAIN: u8 = 10;
const TAG_DRAINING: u8 = 11;
const TAG_INVITE: u8 = 12;
const TAG_SWAP: u8 = 13;

impl Msg {
    /// Serialize to a length-prefixed frame: len u32 | tag u8 | body.
    pub fn to_frame(&self) -> Vec<u8> {
        let mut body = Vec::new();
        let tag = match self {
            Msg::Hello { actor, prior_tau } => {
                body.extend_from_slice(&actor.to_le_bytes());
                body.extend_from_slice(&prior_tau.to_le_bytes());
                TAG_HELLO
            }
            Msg::Seg(seg) => {
                body = seg.to_wire();
                TAG_SEG
            }
            Msg::Commit { version } => {
                body.extend_from_slice(&version.to_le_bytes());
                TAG_COMMIT
            }
            Msg::Activated { actor, version, hash } => {
                body.extend_from_slice(&actor.to_le_bytes());
                body.extend_from_slice(&version.to_le_bytes());
                body.extend_from_slice(hash);
                TAG_ACTIVATED
            }
            Msg::Job { version, rng_seed, prompt_ids } => {
                body.extend_from_slice(&version.to_le_bytes());
                body.extend_from_slice(&rng_seed.to_le_bytes());
                body.extend_from_slice(&(prompt_ids.len() as u32).to_le_bytes());
                for p in prompt_ids {
                    body.extend_from_slice(&p.to_le_bytes());
                }
                TAG_JOB
            }
            Msg::RolloutResult { actor, prompt_id, version, hash, reward, tokens } => {
                body.extend_from_slice(&actor.to_le_bytes());
                body.extend_from_slice(&prompt_id.to_le_bytes());
                body.extend_from_slice(&version.to_le_bytes());
                body.extend_from_slice(hash);
                body.extend_from_slice(&reward.to_le_bytes());
                body.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
                for t in tokens {
                    body.extend_from_slice(&t.to_le_bytes());
                }
                TAG_RESULT
            }
            Msg::Bye => TAG_BYE,
            Msg::Join { actor, prior_tau, region } => {
                body.extend_from_slice(&actor.to_le_bytes());
                body.extend_from_slice(&prior_tau.to_le_bytes());
                body.extend_from_slice(&region.to_le_bytes());
                TAG_JOIN
            }
            Msg::Snapshot { version, hash, data } => {
                body.extend_from_slice(&version.to_le_bytes());
                body.extend_from_slice(hash);
                body.extend_from_slice(&(data.len() as u32).to_le_bytes());
                body.extend_from_slice(data);
                TAG_SNAPSHOT
            }
            Msg::Drain { grace_ms } => {
                body.extend_from_slice(&grace_ms.to_le_bytes());
                TAG_DRAIN
            }
            Msg::Draining { actor } => {
                body.extend_from_slice(&actor.to_le_bytes());
                TAG_DRAINING
            }
            Msg::Invite { actor } => {
                body.extend_from_slice(&actor.to_le_bytes());
                TAG_INVITE
            }
            Msg::Swap { model, version } => {
                body.extend_from_slice(&version.to_le_bytes());
                body.extend_from_slice(&(model.len() as u32).to_le_bytes());
                body.extend_from_slice(model.as_bytes());
                TAG_SWAP
            }
        };
        let mut out = Vec::with_capacity(5 + body.len());
        out.extend_from_slice(&(1 + body.len() as u32).to_le_bytes());
        out.push(tag);
        out.extend_from_slice(&body);
        out
    }

    /// Parse one frame body (after the length prefix was consumed).
    pub fn from_tagged(buf: &[u8]) -> Result<Msg> {
        let (&tag, body) = buf.split_first().context("empty frame")?;
        let rd_u32 = |b: &[u8], at: usize| -> Result<u32> {
            Ok(u32::from_le_bytes(b.get(at..at + 4).context("short")?.try_into()?))
        };
        let rd_u64 = |b: &[u8], at: usize| -> Result<u64> {
            Ok(u64::from_le_bytes(b.get(at..at + 8).context("short")?.try_into()?))
        };
        Ok(match tag {
            TAG_HELLO => Msg::Hello {
                actor: rd_u32(body, 0)?,
                prior_tau: f64::from_le_bytes(body.get(4..12).context("short")?.try_into()?),
            },
            TAG_SEG => {
                let (seg, used) = Segment::from_wire(body).context("bad segment frame")?;
                if used != body.len() {
                    bail!("segment frame trailing bytes");
                }
                Msg::Seg(seg)
            }
            TAG_COMMIT => Msg::Commit { version: rd_u64(body, 0)? },
            TAG_ACTIVATED => {
                let mut hash = [0u8; 32];
                hash.copy_from_slice(body.get(12..44).context("short")?);
                Msg::Activated { actor: rd_u32(body, 0)?, version: rd_u64(body, 4)?, hash }
            }
            TAG_JOB => {
                let version = rd_u64(body, 0)?;
                let rng_seed = rd_u64(body, 8)?;
                let n = rd_u32(body, 16)? as usize;
                // Validate the count against the bytes actually present
                // BEFORE allocating: a hostile prefix must not drive a
                // multi-gigabyte `with_capacity`.
                if body.len() != 20 + n.checked_mul(8).context("prompt count overflow")? {
                    bail!("job frame length mismatch ({n} prompts, {} bytes)", body.len());
                }
                let mut prompt_ids = Vec::with_capacity(n);
                for i in 0..n {
                    prompt_ids.push(rd_u64(body, 20 + i * 8)?);
                }
                Msg::Job { version, rng_seed, prompt_ids }
            }
            TAG_RESULT => {
                let actor = rd_u32(body, 0)?;
                let prompt_id = rd_u64(body, 4)?;
                let version = rd_u64(body, 12)?;
                let mut hash = [0u8; 32];
                hash.copy_from_slice(body.get(20..52).context("short")?);
                let reward = f32::from_le_bytes(body.get(52..56).context("short")?.try_into()?);
                let n = rd_u32(body, 56)? as usize;
                if body.len() != 60 + n.checked_mul(4).context("token count overflow")? {
                    bail!("result frame length mismatch ({n} tokens, {} bytes)", body.len());
                }
                let mut tokens = Vec::with_capacity(n);
                for i in 0..n {
                    tokens.push(i32::from_le_bytes(
                        body.get(60 + i * 4..64 + i * 4).context("short")?.try_into()?,
                    ));
                }
                Msg::RolloutResult { actor, prompt_id, version, hash, reward, tokens }
            }
            TAG_BYE => Msg::Bye,
            TAG_JOIN => Msg::Join {
                actor: rd_u32(body, 0)?,
                prior_tau: f64::from_le_bytes(body.get(4..12).context("short")?.try_into()?),
                region: {
                    if body.len() != 16 {
                        bail!("join frame length mismatch ({} bytes)", body.len());
                    }
                    rd_u32(body, 12)?
                },
            },
            TAG_SNAPSHOT => {
                let version = rd_u64(body, 0)?;
                let mut hash = [0u8; 32];
                hash.copy_from_slice(body.get(8..40).context("short")?);
                let n = rd_u32(body, 40)? as usize;
                // Validate the count against the bytes actually present
                // BEFORE allocating (same rule as Job/RolloutResult), and
                // bind the length so a truncated frame can never parse as
                // a shorter valid snapshot.
                if body.len() != 44usize.checked_add(n).context("snapshot length overflow")? {
                    bail!("snapshot frame length mismatch ({n} data bytes, {} bytes)", body.len());
                }
                Msg::Snapshot { version, hash, data: body[44..].to_vec() }
            }
            TAG_DRAIN => {
                if body.len() != 8 {
                    bail!("drain frame length mismatch ({} bytes)", body.len());
                }
                Msg::Drain { grace_ms: rd_u64(body, 0)? }
            }
            TAG_DRAINING => {
                if body.len() != 4 {
                    bail!("draining frame length mismatch ({} bytes)", body.len());
                }
                Msg::Draining { actor: rd_u32(body, 0)? }
            }
            TAG_INVITE => {
                if body.len() != 4 {
                    bail!("invite frame length mismatch ({} bytes)", body.len());
                }
                Msg::Invite { actor: rd_u32(body, 0)? }
            }
            TAG_SWAP => {
                let version = rd_u64(body, 0)?;
                let n = rd_u32(body, 8)? as usize;
                // Length-bound the name so a truncated frame can never
                // parse as a shorter valid Swap (same rule as Snapshot).
                if body.len() != 12usize.checked_add(n).context("swap name overflow")? {
                    bail!("swap frame length mismatch ({n} name bytes, {} bytes)", body.len());
                }
                let model = std::str::from_utf8(&body[12..])
                    .context("swap model name not utf-8")?
                    .to_string();
                Msg::Swap { model, version }
            }
            other => bail!("unknown tag {other}"),
        })
    }
}

/// Blocking frame reader over any byte stream (sockets in production,
/// in-memory cursors in tests). Frames longer than [`MAX_FRAME`] are
/// rejected before any body allocation.
pub fn read_msg<R: Read>(stream: &mut R) -> Result<Msg> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).context("read frame length")?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME {
        bail!("bad frame length {len} (max {MAX_FRAME})");
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).context("read frame body")?;
    Msg::from_tagged(&body)
}

/// Blocking frame writer over any byte sink.
pub fn write_msg<W: Write>(stream: &mut W, msg: &Msg) -> Result<()> {
    stream.write_all(&msg.to_frame()).context("write frame")?;
    Ok(())
}

/// Token-bucket write throttle: emulates a WAN link's bandwidth on a real
/// socket (the loopback stand-in for the paper's `tc` shaping).
pub struct Throttle {
    bytes_per_s: f64,
    window: Instant,
    sent_in_window: f64,
}

impl Throttle {
    pub fn new(bits_per_s: f64) -> Throttle {
        Throttle { bytes_per_s: bits_per_s / 8.0, window: Instant::now(), sent_in_window: 0.0 }
    }

    /// Account `n` bytes, sleeping as needed to respect the rate.
    pub fn pace(&mut self, n: usize) {
        self.sent_in_window += n as f64;
        let due = self.sent_in_window / self.bytes_per_s;
        let elapsed = self.window.elapsed().as_secs_f64();
        if due > elapsed {
            std::thread::sleep(Duration::from_secs_f64(due - elapsed));
        }
        // Reset the window occasionally to avoid unbounded drift.
        if elapsed > 5.0 {
            self.window = Instant::now();
            self.sent_in_window = 0.0;
        }
    }
}

/// Push a checkpoint's segments over `streams` sockets round-robin,
/// pacing each socket at `bits_per_s / streams` (the per-stream share).
pub fn push_segments_multistream(
    sockets: &mut [TcpStream],
    segments: &[Segment],
    bits_per_s: Option<f64>,
) -> Result<()> {
    let s = sockets.len().max(1);
    let mut throttles: Vec<Option<Throttle>> = (0..s)
        .map(|_| bits_per_s.map(|b| Throttle::new(b / s as f64)))
        .collect();
    for seg in segments {
        let si = crate::transport::stripe::stream_for(seg.seq, s);
        let frame = Msg::Seg(seg.clone()).to_frame();
        if let Some(t) = throttles[si].as_mut() {
            t.pace(frame.len());
        }
        sockets[si].write_all(&frame).context("push segment")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn every_message() -> Vec<Msg> {
        vec![
            Msg::Hello { actor: 3, prior_tau: 2500.0 },
            Msg::Seg(Segment { version: 9, seq: 2, total: 5, payload: vec![1, 2, 3] }),
            Msg::Commit { version: 12 },
            Msg::Activated { actor: 1, version: 12, hash: [7u8; 32] },
            Msg::Job { version: 4, rng_seed: 0xDEAD_BEEF, prompt_ids: vec![10, 20, 30] },
            Msg::RolloutResult {
                actor: 2,
                prompt_id: 77,
                version: 4,
                hash: [9u8; 32],
                reward: 0.5,
                tokens: vec![1, -2, 3],
            },
            Msg::Bye,
            Msg::Join { actor: 5, prior_tau: 1800.0, region: 2 },
            Msg::Snapshot { version: 6, hash: [3u8; 32], data: vec![0xAB, 0xCD, 0xEF] },
            Msg::Drain { grace_ms: 1500 },
            Msg::Draining { actor: 4 },
            Msg::Invite { actor: 5 },
            Msg::Swap { model: "ft-math.v2".to_string(), version: 8 },
        ]
    }

    fn round_trip(m: Msg) {
        let frame = m.to_frame();
        let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        let back = Msg::from_tagged(&frame[4..]).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn all_messages_round_trip() {
        for m in every_message() {
            round_trip(m);
        }
    }

    #[test]
    fn stream_round_trip_through_reader_and_writer() {
        // The exact path the Tcp backend uses: write_msg onto a byte
        // stream, read_msg back, for the full vocabulary back to back.
        let mut buf: Vec<u8> = Vec::new();
        for m in every_message() {
            write_msg(&mut buf, &m).unwrap();
        }
        let mut rd = Cursor::new(buf);
        for want in every_message() {
            assert_eq!(read_msg(&mut rd).unwrap(), want);
        }
        assert!(read_msg(&mut rd).is_err(), "clean EOF after the last frame");
    }

    #[test]
    fn corrupt_segment_frame_rejected() {
        let m = Msg::Seg(Segment { version: 1, seq: 0, total: 1, payload: vec![5; 64] });
        let mut frame = m.to_frame();
        let n = frame.len();
        frame[n - 3] ^= 0xFF;
        assert!(Msg::from_tagged(&frame[4..]).is_err());
    }

    #[test]
    fn truncated_frames_error_instead_of_panicking() {
        // Every prefix of every message body must decode to Err — never
        // panic, never misparse into a shorter valid message.
        for m in every_message() {
            let frame = m.to_frame();
            let body = &frame[4..];
            for cut in 0..body.len() {
                match Msg::from_tagged(&body[..cut]) {
                    Err(_) => {}
                    // A Seg prefix could only "succeed" if it were a
                    // full shorter segment; the trailing-bytes check and
                    // per-segment checksum forbid that.
                    Ok(got) => panic!("prefix {cut} of {m:?} parsed as {got:?}"),
                }
            }
        }
    }

    #[test]
    fn unknown_and_empty_tags_rejected() {
        assert!(Msg::from_tagged(&[]).is_err(), "empty frame");
        for tag in [0u8, 14, 99, 255] {
            assert!(Msg::from_tagged(&[tag]).is_err(), "tag {tag}");
            assert!(Msg::from_tagged(&[tag, 1, 2, 3]).is_err(), "tag {tag} with body");
        }
    }

    #[test]
    fn hostile_counts_are_capped_before_allocation() {
        // A Job body claiming u32::MAX prompts but carrying none: the
        // count/length cross-check must reject it without ever reserving
        // 32 GB. (If the cap regressed, this test would OOM/abort rather
        // than fail an assert — either way CI catches it.)
        let mut body = vec![TAG_JOB];
        body.extend_from_slice(&4u64.to_le_bytes()); // version
        body.extend_from_slice(&7u64.to_le_bytes()); // rng_seed
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // n, hostile
        assert!(Msg::from_tagged(&body).is_err());

        let mut body = vec![TAG_RESULT];
        body.extend_from_slice(&[0u8; 56]); // actor..reward, all zero
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // n tokens, hostile
        body.extend_from_slice(&[0u8; 64]); // some bytes, far fewer than claimed
        assert!(Msg::from_tagged(&body).is_err());

        // Trailing garbage after a valid count is also a length mismatch.
        let mut frame = Msg::Job { version: 1, rng_seed: 2, prompt_ids: vec![5] }.to_frame();
        frame.extend_from_slice(&[0u8; 8]);
        assert!(Msg::from_tagged(&frame[4..]).is_err());

        // A Snapshot claiming 4 GiB of params while carrying none must be
        // rejected by the count/length cross-check, never allocated.
        let mut body = vec![TAG_SNAPSHOT];
        body.extend_from_slice(&3u64.to_le_bytes()); // version
        body.extend_from_slice(&[0u8; 32]); // hash
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // n data bytes, hostile
        assert!(Msg::from_tagged(&body).is_err());
    }

    #[test]
    fn read_msg_rejects_oversized_and_zero_length_prefixes() {
        // len > MAX_FRAME: reject from the 4-byte prefix alone — the body
        // is never allocated or read.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        buf.extend_from_slice(&[TAG_BYE]);
        assert!(read_msg(&mut Cursor::new(&buf)).is_err());
        // len == 0: no room for even a tag.
        assert!(read_msg(&mut Cursor::new(&0u32.to_le_bytes())).is_err());
        // Truncated body: length prefix promises more than the stream has.
        let mut buf = Vec::new();
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.push(TAG_BYE);
        assert!(read_msg(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn throttle_enforces_rate() {
        // 8 Mbit/s = 1 MB/s; sending 200 KB should take ~0.2 s.
        let mut t = Throttle::new(8e6);
        let t0 = Instant::now();
        for _ in 0..20 {
            t.pace(10_000);
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.15, "throttle too loose: {dt:.3}s");
        assert!(dt < 0.6, "throttle too tight: {dt:.3}s");
    }
}
