//! `bench compare OLD NEW`: the regression gate over two result sets.
//!
//! Records are joined per scenario key. For each gated metric the
//! verdict depends on its [`Better`] direction: `Exact` metrics fail on
//! any drift; `Lower`/`Higher` metrics fail when they move in the worse
//! direction by more than the threshold (and are reported as
//! improvements when they move the other way that far). A changed
//! determinism witness is always a failure — that is the bit-exactness
//! guarantee becoming machine-checkable. A key present in OLD but
//! missing from NEW fails (scenario coverage regressed); a new key is
//! reported and passes. Ungated gauges (timings) never gate, so a
//! committed baseline stays valid across machines.
//!
//! A `placeholder` OLD (the committed bootstrap baseline) passes
//! unconditionally and prints a re-baseline notice — see bench/README.md.

use crate::bench::summary::{Better, ResultSet};
use std::fmt::Write as _;

pub const DEFAULT_THRESHOLD_PCT: f64 = 5.0;

/// Outcome of one scenario key's diff.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellStatus {
    Ok,
    /// At least one gated metric moved past the threshold in the better
    /// direction (and none regressed).
    Improved,
    /// At least one gating failure (regression, exact drift, witness
    /// mismatch, or a gated metric disappearing).
    Regressed,
    /// In OLD but not NEW: the matrix lost coverage.
    Missing,
    /// In NEW only: fresh coverage, never a failure.
    Added,
}

impl CellStatus {
    fn label(self) -> &'static str {
        match self {
            CellStatus::Ok => "ok",
            CellStatus::Improved => "improved",
            CellStatus::Regressed => "REGRESSED",
            CellStatus::Missing => "MISSING",
            CellStatus::Added => "added",
        }
    }
}

/// One scenario key's rendered diff.
#[derive(Clone, Debug)]
pub struct CellDiff {
    pub key: String,
    pub status: CellStatus,
    /// Human-readable gating failures (empty unless Regressed/Missing).
    pub failures: Vec<String>,
    /// Beyond-threshold moves in the better direction.
    pub improvements: Vec<String>,
}

/// The full diff: render with [`CompareReport::render`], gate CI with
/// [`CompareReport::passed`].
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub threshold_pct: f64,
    pub old_suite: String,
    pub new_suite: String,
    pub baseline_placeholder: bool,
    pub suite_mismatch: bool,
    pub cells: Vec<CellDiff>,
}

impl CompareReport {
    pub fn passed(&self) -> bool {
        !self.suite_mismatch
            && self
                .cells
                .iter()
                .all(|c| !matches!(c.status, CellStatus::Regressed | CellStatus::Missing))
    }

    pub fn failures(&self) -> usize {
        self.cells.iter().map(|c| c.failures.len()).sum::<usize>()
            + usize::from(self.suite_mismatch)
    }

    /// The summary table `bench compare` prints.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "bench compare: {} -> {} (gated metrics, threshold ±{}%)",
            self.old_suite, self.new_suite, self.threshold_pct
        );
        if self.baseline_placeholder {
            let _ = writeln!(
                out,
                "  baseline is a placeholder: every cell below is fresh; promote the new \
                 results to re-baseline (see bench/README.md)"
            );
        }
        if self.suite_mismatch {
            let _ = writeln!(
                out,
                "  SUITE MISMATCH: comparing {:?} against {:?} is not meaningful",
                self.old_suite, self.new_suite
            );
        }
        let width = self.cells.iter().map(|c| c.key.len()).max().unwrap_or(8).max(8);
        for cell in &self.cells {
            let _ = writeln!(out, "  {:<width$}  {}", cell.key, cell.status.label());
            for f in &cell.failures {
                let _ = writeln!(out, "  {:<width$}    !! {}", "", f);
            }
            for imp in &cell.improvements {
                let _ = writeln!(out, "  {:<width$}    ++ {}", "", imp);
            }
        }
        let count = |s: CellStatus| self.cells.iter().filter(|c| c.status == s).count();
        let _ = writeln!(
            out,
            "summary: {} cell(s): {} ok, {} improved, {} regressed, {} missing, {} added -> {}",
            self.cells.len(),
            count(CellStatus::Ok),
            count(CellStatus::Improved),
            count(CellStatus::Regressed),
            count(CellStatus::Missing),
            count(CellStatus::Added),
            if self.passed() { "PASS" } else { "FAIL" },
        );
        out
    }
}

/// Diff `new` against the `old` baseline.
pub fn compare(old: &ResultSet, new: &ResultSet, threshold_pct: f64) -> CompareReport {
    let mut report = CompareReport {
        threshold_pct,
        old_suite: old.suite.clone(),
        new_suite: new.suite.clone(),
        baseline_placeholder: old.placeholder,
        suite_mismatch: !old.placeholder && old.suite != new.suite,
        cells: Vec::new(),
    };
    // OLD's order first (stable against the baseline), then NEW-only keys.
    for rec in &old.records {
        let Some(new_rec) = new.get(&rec.key) else {
            report.cells.push(CellDiff {
                key: rec.key.clone(),
                status: CellStatus::Missing,
                failures: vec!["scenario missing from NEW results (coverage regressed)".into()],
                improvements: Vec::new(),
            });
            continue;
        };
        let mut failures = Vec::new();
        let mut improvements = Vec::new();
        if let (Some(ow), nw) = (&rec.witness, &new_rec.witness) {
            if nw.as_ref() != Some(ow) {
                failures.push(format!(
                    "determinism witness changed: {} -> {}",
                    short(ow),
                    nw.as_deref().map(short).unwrap_or_else(|| "(none)".into()),
                ));
            }
        }
        for (name, m_old) in rec.metrics.iter().filter(|(_, m)| m.gated) {
            let Some(m_new) = new_rec.metrics.get(name) else {
                failures.push(format!("gated metric {name} missing from NEW"));
                continue;
            };
            match m_old.better {
                Better::Exact => {
                    if m_new.value != m_old.value {
                        failures.push(format!(
                            "{name}: {} -> {} (exact metric drifted)",
                            m_old.value, m_new.value
                        ));
                    }
                }
                Better::Lower | Better::Higher => {
                    let delta_pct = if m_old.value == 0.0 {
                        if m_new.value == 0.0 {
                            0.0
                        } else {
                            f64::INFINITY * (m_new.value - m_old.value).signum()
                        }
                    } else {
                        (m_new.value - m_old.value) / m_old.value.abs() * 100.0
                    };
                    let worse = match m_old.better {
                        Better::Lower => delta_pct > 0.0,
                        _ => delta_pct < 0.0,
                    };
                    if delta_pct.abs() > threshold_pct {
                        let line = format!(
                            "{name}: {} -> {} ({delta_pct:+.1}%)",
                            m_old.value, m_new.value
                        );
                        if worse {
                            failures.push(line);
                        } else {
                            improvements.push(line);
                        }
                    }
                }
            }
        }
        let status = if !failures.is_empty() {
            CellStatus::Regressed
        } else if !improvements.is_empty() {
            CellStatus::Improved
        } else {
            CellStatus::Ok
        };
        report.cells.push(CellDiff { key: rec.key.clone(), status, failures, improvements });
    }
    for rec in &new.records {
        if old.get(&rec.key).is_none() {
            report.cells.push(CellDiff {
                key: rec.key.clone(),
                status: CellStatus::Added,
                failures: Vec::new(),
                improvements: Vec::new(),
            });
        }
    }
    report
}

fn short(w: &str) -> String {
    w.chars().take(12).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::summary::{ResultRecord, ResultSet};

    fn set_with(payload: f64, witness: &str) -> ResultSet {
        let mut s = ResultSet::new("t");
        s.push(
            ResultRecord::new("syn-xs/r1/inproc/none/default/seed0")
                .gate("payload_bytes", payload, Better::Lower)
                .gauge("makespan_s", 1.0)
                .with_witness(witness),
        );
        s
    }

    #[test]
    fn self_compare_passes_and_gauges_never_gate() {
        let a = set_with(1000.0, "aa");
        let mut b = set_with(1000.0, "aa");
        // A wildly different timing gauge must not gate.
        b.records[0].metrics.get_mut("makespan_s").unwrap().value = 99.0;
        let rep = compare(&a, &b, 5.0);
        assert!(rep.passed(), "{}", rep.render());
        assert_eq!(rep.cells[0].status, CellStatus::Ok);
    }

    #[test]
    fn regression_beyond_threshold_fails_and_improvement_passes() {
        let base = set_with(1000.0, "aa");
        let rep = compare(&base, &set_with(1200.0, "aa"), 5.0);
        assert!(!rep.passed());
        assert_eq!(rep.cells[0].status, CellStatus::Regressed);
        let rep = compare(&base, &set_with(700.0, "aa"), 5.0);
        assert!(rep.passed());
        assert_eq!(rep.cells[0].status, CellStatus::Improved);
        // Within noise: 3% growth under a 5% threshold.
        assert_eq!(compare(&base, &set_with(1030.0, "aa"), 5.0).cells[0].status, CellStatus::Ok);
    }

    #[test]
    fn witness_mismatch_always_fails() {
        let rep = compare(&set_with(1000.0, "aa"), &set_with(1000.0, "bb"), 50.0);
        assert!(!rep.passed());
        assert!(rep.cells[0].failures[0].contains("witness"));
    }

    #[test]
    fn placeholder_baseline_passes_with_every_cell_added() {
        let mut old = ResultSet::new("smoke");
        old.placeholder = true;
        let rep = compare(&old, &set_with(1000.0, "aa"), 5.0);
        assert!(rep.passed());
        assert_eq!(rep.cells[0].status, CellStatus::Added);
        assert!(rep.render().contains("placeholder"));
    }
}
