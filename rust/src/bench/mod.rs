//! `sparrowrl bench`: the declarative scenario-matrix harness.
//!
//! Replaces the bespoke per-bench JSON emitters with one schema and one
//! gate (ROADMAP open item 3):
//!
//! * [`scenario`] — declarative cells {model} × {regions 1–4} ×
//!   {transport} × {fault} × {sparsity} × {seed}, expanded from built-in
//!   suites (`smoke`, `full`) or a JSON file, validated with typed
//!   errors before anything runs.
//! * [`runner`] — executes each cell through the `Session` API on
//!   `SyntheticCompute` and folds the report into a result record.
//! * [`summary`] — the result-record schema: gated deterministic
//!   metrics + ungated timing gauges + the SHA-256 determinism witness,
//!   round-tripped through one JSON file per run.
//! * [`compare`] — diffs two result sets per scenario key and fails
//!   (nonzero exit) on regression beyond a threshold, on any drift of an
//!   exact metric, or on a changed witness. This is the CI gate
//!   (`bench-gate` job) that makes scenario diversity enforceable.

pub mod compare;
pub mod runner;
pub mod scenario;
pub mod summary;

pub use compare::{compare, CompareReport, DEFAULT_THRESHOLD_PCT};
pub use runner::{run_scenario, run_suite};
pub use scenario::{builtin_suite, Scenario, ScenarioBlock, ScenarioError, Suite, SUITE_NAMES};
pub use summary::{Better, Metric, ResultRecord, ResultSet, SummaryError, SCHEMA_VERSION};
