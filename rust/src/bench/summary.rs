//! Result-record schema for the scenario-matrix harness.
//!
//! One `bench run` emits one [`ResultSet`]: a suite name plus one
//! [`ResultRecord`] per scenario cell. A record is a flat bag of named
//! [`Metric`]s split into two classes:
//!
//! * **gated** — deterministic under the replayed schedule (payload
//!   bytes, rho, membership counts). `bench compare` diffs these against
//!   a baseline and fails CI beyond the threshold (or on *any* drift for
//!   [`Better::Exact`] metrics).
//! * **gauges** — machine-dependent timings (makespan, tok/s, tok/$).
//!   Recorded for the perf trajectory, never gated, so a committed
//!   baseline stays valid across runner hardware.
//!
//! The optional `witness` is the final committed policy's SHA-256 hex —
//! the bit-exactness guarantee as one comparable string per cell.
//!
//! Serialization goes through `util::jsonl::Json` (the offline serde
//! stand-in); non-finite metric values are a typed [`SummaryError`]
//! rather than a silent JSON `null`, mirroring the `util::bench`
//! `BenchWriteError` policy.

use crate::util::bench::Bencher;
use crate::util::jsonl::Json;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Bumped when the result-record layout changes incompatibly; `compare`
/// refuses to diff files from a different schema generation.
pub const SCHEMA_VERSION: u64 = 1;

/// Regression direction of a gated metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    /// Smaller is better (payload bytes, rho): growth beyond the
    /// threshold is a regression, shrinkage an improvement.
    Lower,
    /// Larger is better (throughput-style counters).
    Higher,
    /// Any change at all is a failure (failover/join/drain counts,
    /// final version): these are schedule invariants, not trends.
    Exact,
}

impl Better {
    pub fn name(self) -> &'static str {
        match self {
            Better::Lower => "lower",
            Better::Higher => "higher",
            Better::Exact => "exact",
        }
    }

    pub fn parse(s: &str) -> Option<Better> {
        match s {
            "lower" => Some(Better::Lower),
            "higher" => Some(Better::Higher),
            "exact" => Some(Better::Exact),
            _ => None,
        }
    }
}

/// One named measurement inside a record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Metric {
    pub value: f64,
    pub better: Better,
    /// Gated metrics participate in `bench compare`; gauges are
    /// informational only (timings vary by machine).
    pub gated: bool,
}

/// One scenario cell's results, keyed by the scenario's canonical key
/// (e.g. `syn-xs/r1/tcp/crash/default/seed0`).
#[derive(Clone, Debug, PartialEq)]
pub struct ResultRecord {
    pub key: String,
    /// The scenario axes verbatim, for filtering and coverage checks.
    pub axes: BTreeMap<String, String>,
    pub metrics: BTreeMap<String, Metric>,
    /// Final committed policy SHA-256 (hex) — the determinism witness.
    pub witness: Option<String>,
}

impl ResultRecord {
    pub fn new(key: &str) -> ResultRecord {
        ResultRecord {
            key: key.to_string(),
            axes: BTreeMap::new(),
            metrics: BTreeMap::new(),
            witness: None,
        }
    }

    pub fn axis(mut self, name: &str, value: &str) -> ResultRecord {
        self.axes.insert(name.to_string(), value.to_string());
        self
    }

    /// Record an informational (never gated) metric.
    pub fn gauge(mut self, name: &str, value: f64) -> ResultRecord {
        self.metrics
            .insert(name.to_string(), Metric { value, better: Better::Lower, gated: false });
        self
    }

    /// Record a gated metric: `compare` fails on regression past the
    /// threshold (`Lower`/`Higher`) or on any drift (`Exact`).
    pub fn gate(mut self, name: &str, value: f64, better: Better) -> ResultRecord {
        self.metrics.insert(name.to_string(), Metric { value, better, gated: true });
        self
    }

    pub fn with_witness(mut self, hex: &str) -> ResultRecord {
        self.witness = Some(hex.to_string());
        self
    }

    fn to_json(&self) -> Json {
        let mut axes = Json::obj();
        for (k, v) in &self.axes {
            axes = axes.set(k, v.as_str());
        }
        let mut metrics = Json::obj();
        for (k, m) in &self.metrics {
            metrics = metrics.set(
                k,
                Json::obj()
                    .set("v", m.value)
                    .set("better", m.better.name())
                    .set("gated", m.gated),
            );
        }
        let mut j = Json::obj().set("key", self.key.as_str()).set("axes", axes).set(
            "metrics",
            metrics,
        );
        if let Some(w) = &self.witness {
            j = j.set("witness", w.as_str());
        }
        j
    }

    fn from_json(j: &Json) -> Result<ResultRecord, SummaryError> {
        let key = j
            .get("key")
            .and_then(Json::as_str)
            .ok_or_else(|| SummaryError::malformed("record without a string \"key\""))?
            .to_string();
        let mut rec = ResultRecord::new(&key);
        if let Some(Json::Obj(m)) = j.get("axes") {
            for (k, v) in m {
                let v = v.as_str().ok_or_else(|| {
                    SummaryError::malformed(format!("{key}: axis {k:?} is not a string"))
                })?;
                rec.axes.insert(k.clone(), v.to_string());
            }
        }
        let Some(Json::Obj(m)) = j.get("metrics") else {
            return Err(SummaryError::malformed(format!("{key}: missing \"metrics\" object")));
        };
        for (name, mj) in m {
            let value = mj.get("v").and_then(Json::as_f64).ok_or_else(|| {
                SummaryError::malformed(format!("{key}: metric {name:?} without a numeric \"v\""))
            })?;
            let better = mj
                .get("better")
                .and_then(Json::as_str)
                .and_then(Better::parse)
                .ok_or_else(|| {
                    SummaryError::malformed(format!(
                        "{key}: metric {name:?} needs \"better\": lower|higher|exact"
                    ))
                })?;
            let gated = mj.get("gated").and_then(Json::as_bool).unwrap_or(false);
            rec.metrics.insert(name.clone(), Metric { value, better, gated });
        }
        if let Some(w) = j.get("witness") {
            rec.witness = Some(
                w.as_str()
                    .ok_or_else(|| {
                        SummaryError::malformed(format!("{key}: witness is not a string"))
                    })?
                    .to_string(),
            );
        }
        Ok(rec)
    }
}

/// One `bench run`'s output: every scenario cell's record plus the suite
/// identity, in scenario order.
#[derive(Clone, Debug, PartialEq)]
pub struct ResultSet {
    pub suite: String,
    pub schema: u64,
    /// A committed placeholder baseline: `compare` treats every NEW cell
    /// as freshly added and passes, printing a re-baseline notice. This
    /// is how `bench/baseline_smoke.json` bootstraps before the first
    /// real CI run is promoted (see bench/README.md).
    pub placeholder: bool,
    pub records: Vec<ResultRecord>,
}

impl ResultSet {
    pub fn new(suite: &str) -> ResultSet {
        ResultSet {
            suite: suite.to_string(),
            schema: SCHEMA_VERSION,
            placeholder: false,
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, rec: ResultRecord) {
        self.records.push(rec);
    }

    pub fn get(&self, key: &str) -> Option<&ResultRecord> {
        self.records.iter().find(|r| r.key == key)
    }

    /// Lift a `util::bench::Bencher`'s timing cases onto the harness
    /// schema: one record per case, all timings as (ungated) gauges.
    /// The legacy `BENCH_*.json` emitters feed their deterministic byte
    /// counts in as gated records alongside these.
    pub fn from_bencher(suite: &str, b: &Bencher) -> ResultSet {
        let mut set = ResultSet::new(suite);
        for r in b.results() {
            let mut rec = ResultRecord::new(&format!("{suite}/{}", r.name))
                .axis("case", &r.name)
                .gauge("reps", r.reps as f64)
                .gauge("min_s", r.min.as_secs_f64())
                .gauge("median_s", r.median.as_secs_f64())
                .gauge("mean_s", r.mean.as_secs_f64())
                .gauge("p95_s", r.p95.as_secs_f64());
            if let Some(t) = r.throughput_gbps().filter(|t| t.is_finite()) {
                rec = rec.gauge("gb_per_s", t);
            }
            set.push(rec);
        }
        set
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema", self.schema)
            .set("suite", self.suite.as_str())
            .set("placeholder", self.placeholder)
            .set("records", Json::Arr(self.records.iter().map(|r| r.to_json()).collect()))
    }

    pub fn parse(s: &str) -> Result<ResultSet, SummaryError> {
        let j = Json::parse(s).map_err(SummaryError::Parse)?;
        let schema = j
            .get("schema")
            .and_then(Json::as_u64)
            .ok_or_else(|| SummaryError::malformed("missing numeric \"schema\""))?;
        if schema != SCHEMA_VERSION {
            return Err(SummaryError::SchemaVersion { found: schema, expected: SCHEMA_VERSION });
        }
        let suite = j
            .get("suite")
            .and_then(Json::as_str)
            .ok_or_else(|| SummaryError::malformed("missing string \"suite\""))?;
        let mut set = ResultSet::new(suite);
        set.placeholder = j.get("placeholder").and_then(Json::as_bool).unwrap_or(false);
        let records = j
            .get("records")
            .and_then(Json::as_arr)
            .ok_or_else(|| SummaryError::malformed("missing \"records\" array"))?;
        for r in records {
            set.records.push(ResultRecord::from_json(r)?);
        }
        Ok(set)
    }

    pub fn load(path: &Path) -> Result<ResultSet, SummaryError> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| SummaryError::Io { path: path.display().to_string(), err: e.to_string() })?;
        ResultSet::parse(&s)
    }

    /// Serialize to `path`. Rejects non-finite metric values with a typed
    /// error *before* touching the file: `Json` would emit `null` for
    /// NaN/Inf and the file would no longer parse back as a ResultSet.
    pub fn write(&self, path: &Path) -> Result<(), SummaryError> {
        for rec in &self.records {
            for (name, m) in &rec.metrics {
                if !m.value.is_finite() {
                    return Err(SummaryError::NonFinite {
                        key: rec.key.clone(),
                        metric: name.clone(),
                    });
                }
            }
        }
        std::fs::write(path, self.to_json().to_string() + "\n")
            .map_err(|e| SummaryError::Io { path: path.display().to_string(), err: e.to_string() })
    }
}

/// Typed failures of the result-file round trip.
#[derive(Clone, Debug, PartialEq)]
pub enum SummaryError {
    Io { path: String, err: String },
    /// JSON syntax error (byte offset from `Json::parse`).
    Parse(String),
    /// Parsed JSON that is not a well-formed result set.
    Malformed(String),
    SchemaVersion { found: u64, expected: u64 },
    /// A metric value JSON cannot represent losslessly.
    NonFinite { key: String, metric: String },
}

impl SummaryError {
    fn malformed(what: impl Into<String>) -> SummaryError {
        SummaryError::Malformed(what.into())
    }
}

impl fmt::Display for SummaryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SummaryError::Io { path, err } => write!(f, "{path}: {err}"),
            SummaryError::Parse(e) => write!(f, "invalid JSON: {e}"),
            SummaryError::Malformed(what) => write!(f, "malformed result set: {what}"),
            SummaryError::SchemaVersion { found, expected } => write!(
                f,
                "result schema v{found} != v{expected}; regenerate with this binary's `bench run`"
            ),
            SummaryError::NonFinite { key, metric } => write!(
                f,
                "{key}: metric {metric:?} is NaN/Inf, which JSON cannot represent losslessly"
            ),
        }
    }
}

impl std::error::Error for SummaryError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ResultSet {
        let mut set = ResultSet::new("smoke");
        set.push(
            ResultRecord::new("syn-xs/r1/inproc/none/default/seed0")
                .axis("transport", "inproc")
                .axis("regions", "1")
                .gate("payload_bytes", 1234.0, Better::Lower)
                .gate("failovers", 0.0, Better::Exact)
                .gauge("makespan_s", 0.25)
                .with_witness("ab12cd"),
        );
        set.push(ResultRecord::new("syn-xs/r1/tcp/crash/default/seed0").gate(
            "rho",
            0.015625,
            Better::Lower,
        ));
        set
    }

    #[test]
    fn result_set_round_trips_bit_exactly() {
        let set = sample();
        let doc = set.to_json().to_string();
        let back = ResultSet::parse(&doc).unwrap();
        assert_eq!(back, set);
        // Gated/gauge split and witness survive the trip.
        let r = back.get("syn-xs/r1/inproc/none/default/seed0").unwrap();
        assert!(r.metrics["payload_bytes"].gated);
        assert!(!r.metrics["makespan_s"].gated);
        assert_eq!(r.metrics["failovers"].better, Better::Exact);
        assert_eq!(r.witness.as_deref(), Some("ab12cd"));
    }

    #[test]
    fn write_rejects_non_finite_metrics_with_a_typed_error() {
        let mut set = sample();
        set.records[0]
            .metrics
            .insert("bad".into(), Metric { value: f64::NAN, better: Better::Lower, gated: false });
        let path = std::env::temp_dir().join(format!("sprw-summary-{}.json", std::process::id()));
        match set.write(&path) {
            Err(SummaryError::NonFinite { key, metric }) => {
                assert_eq!(key, "syn-xs/r1/inproc/none/default/seed0");
                assert_eq!(metric, "bad");
            }
            other => panic!("expected NonFinite, got {other:?}"),
        }
        assert!(!path.exists(), "rejected write must not leave a file behind");
    }

    #[test]
    fn schema_version_mismatch_is_typed() {
        let doc = r#"{"schema":99,"suite":"x","placeholder":false,"records":[]}"#;
        assert_eq!(
            ResultSet::parse(doc),
            Err(SummaryError::SchemaVersion { found: 99, expected: SCHEMA_VERSION })
        );
    }

    #[test]
    fn from_bencher_lifts_cases_as_ungated_gauges() {
        let mut b = Bencher::new(0, 3);
        b.bench("alpha", || {
            std::hint::black_box(1 + 1);
        });
        let set = ResultSet::from_bencher("bench-x", &b);
        assert_eq!(set.records.len(), 1);
        let r = &set.records[0];
        assert_eq!(r.key, "bench-x/alpha");
        assert!(r.metrics.values().all(|m| !m.gated));
        assert!(r.metrics.contains_key("median_s"));
    }
}
