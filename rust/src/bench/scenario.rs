//! Declarative scenario matrix for `sparrowrl bench`.
//!
//! A [`Scenario`] is one cell of {model preset} × {regions 1–4} ×
//! {transport} × {fault script} × {sparsity regime} × {seed}; a
//! [`Suite`] is a list of [`ScenarioBlock`] sub-matrices that expand to
//! the cell list. Expansion validates every cell up front with a typed
//! [`ScenarioError`] (mirroring `session::SpecError`) so an illegal
//! matrix never fails at runtime mid-suite.
//!
//! Cross-field legality mirrors the `RunSpec::build` rules (see
//! `session/spec.rs`): multi-region runs need the relay tree (inproc) or
//! netsim, never raw Tcp; elastic membership (join/drain) is pinned to a
//! flat fleet on inproc/tcp; crash/preempt kill a real socket and so need
//! the Tcp backend.

use crate::delta::ModelLayout;
use crate::util::jsonl::Json;
use std::collections::BTreeSet;
use std::fmt;

/// Transport axis — `Backend` minus the explicit-topology `SimNet`
/// variant (scenarios derive topology from the region axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransportAxis {
    InProc,
    Sim,
    Tcp,
}

impl TransportAxis {
    pub const ALL: [TransportAxis; 3] = [TransportAxis::InProc, TransportAxis::Sim, TransportAxis::Tcp];

    pub fn name(self) -> &'static str {
        match self {
            TransportAxis::InProc => "inproc",
            TransportAxis::Sim => "sim",
            TransportAxis::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Option<TransportAxis> {
        Self::ALL.into_iter().find(|t| t.name() == s)
    }
}

/// Fault-script axis: one canonical fault per cell, pinned at the run's
/// final step version (`steps - 2`) — the strongest determinism point,
/// where a faulted run must still match the healthy baseline bitwise
/// (proven by `tests/transport_fault.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultAxis {
    None,
    /// Live join (delta-chain bootstrap) of one extra actor.
    Join,
    /// Graceful drain of one actor.
    Drain,
    /// Socket-slam crash of one actor (lease-driven failover).
    Crash,
    /// Spot preemption, warn-then-kill with a zero warning window.
    Preempt,
}

impl FaultAxis {
    pub const ALL: [FaultAxis; 5] =
        [FaultAxis::None, FaultAxis::Join, FaultAxis::Drain, FaultAxis::Crash, FaultAxis::Preempt];

    pub fn name(self) -> &'static str {
        match self {
            FaultAxis::None => "none",
            FaultAxis::Join => "join",
            FaultAxis::Drain => "drain",
            FaultAxis::Crash => "crash",
            FaultAxis::Preempt => "preempt",
        }
    }

    pub fn parse(s: &str) -> Option<FaultAxis> {
        Self::ALL.into_iter().find(|f| f.name() == s)
    }

    /// Join/drain reshape membership (spec-level scripting); crash and
    /// preempt are transport-level kill injections.
    pub fn is_elastic(self) -> bool {
        matches!(self, FaultAxis::Join | FaultAxis::Drain)
    }

    pub fn is_kill(self) -> bool {
        matches!(self, FaultAxis::Crash | FaultAxis::Preempt)
    }
}

/// Sparsity-regime axis: how many elements each synthetic train step
/// touches per tensor (`len / divisor`, min 1) — the knob the related
/// work says behavior shifts along (SparseRL-Sync; "RL Fine-Tunes a
/// Sparse Subnetwork").
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SparsityAxis {
    /// 1/16 of each tensor per step — dense-ish updates.
    Dense,
    /// 1/128 (the historical `SyntheticCompute` default).
    Default,
    /// 1/1024 — the stable-subnetwork regime.
    Sparse,
}

impl SparsityAxis {
    pub const ALL: [SparsityAxis; 3] =
        [SparsityAxis::Dense, SparsityAxis::Default, SparsityAxis::Sparse];

    pub fn name(self) -> &'static str {
        match self {
            SparsityAxis::Dense => "dense",
            SparsityAxis::Default => "default",
            SparsityAxis::Sparse => "sparse",
        }
    }

    pub fn parse(s: &str) -> Option<SparsityAxis> {
        Self::ALL.into_iter().find(|x| x.name() == s)
    }

    pub fn update_divisor(self) -> usize {
        match self {
            SparsityAxis::Dense => 16,
            SparsityAxis::Default => 128,
            SparsityAxis::Sparse => 1024,
        }
    }
}

/// A synthetic bench model preset: layout plus compute batch geometry.
#[derive(Clone, Debug)]
pub struct BenchModel {
    pub name: &'static str,
    pub layout: ModelLayout,
    pub b_train: usize,
    pub b_gen: usize,
    pub max_seq: usize,
}

/// The model-preset axis (`syn-xs` < `syn-s` < `syn-m` by parameter
/// count). Separate from `config::model` presets on purpose: bench
/// models pin the layouts benchmarks have always used, independent of
/// the trainable-model catalog.
pub const BENCH_MODEL_NAMES: [&str; 3] = ["syn-xs", "syn-s", "syn-m"];

pub fn bench_model(name: &str) -> Option<BenchModel> {
    let (name, vocab, d_model, n_layers, d_ff) = match name {
        "syn-xs" => ("syn-xs", 256, 64, 2, 128),
        "syn-s" => ("syn-s", 512, 128, 2, 256),
        "syn-m" => ("syn-m", 1024, 256, 4, 512),
        _ => return None,
    };
    Some(BenchModel {
        name,
        layout: ModelLayout::transformer(name, vocab, d_model, n_layers, d_ff),
        b_train: 16,
        b_gen: 8,
        max_seq: 64,
    })
}

/// One fully specified scenario cell.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub model: String,
    /// 1 = flat 3-actor fleet; 2..=4 = the matching `wan-N` preset
    /// (2 actors per region, relay-routed).
    pub regions: usize,
    pub transport: TransportAxis,
    pub fault: FaultAxis,
    pub sparsity: SparsityAxis,
    pub seed: u64,
    pub steps: u64,
}

impl Scenario {
    /// Canonical identity: the join key `bench compare` matches records
    /// on, and the `key` field of the emitted [`super::ResultRecord`].
    pub fn key(&self) -> String {
        format!(
            "{}/r{}/{}/{}/{}/seed{}",
            self.model,
            self.regions,
            self.transport.name(),
            self.fault.name(),
            self.sparsity.name(),
            self.seed,
        )
    }

    /// Every cross-field legality rule, checked before any cell runs.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        if bench_model(&self.model).is_none() {
            return Err(ScenarioError::UnknownModel(self.model.clone()));
        }
        if self.regions == 0 || self.regions > 4 {
            return Err(ScenarioError::RegionsOutOfRange { regions: self.regions });
        }
        if self.steps == 0 {
            return Err(ScenarioError::ZeroSteps);
        }
        if self.regions > 1 && self.transport == TransportAxis::Tcp {
            return Err(ScenarioError::WanConflictsWithTcp { key: self.key() });
        }
        if self.fault != FaultAxis::None {
            // Fault pins land at version `steps - 2` (the final step), so
            // the pin must still be a committed version >= 1.
            if self.steps < 3 {
                return Err(ScenarioError::TooFewStepsForFault {
                    key: self.key(),
                    steps: self.steps,
                });
            }
            if self.regions > 1 {
                return Err(ScenarioError::WanConflictsWithFault { key: self.key() });
            }
        }
        if self.fault.is_elastic() && self.transport == TransportAxis::Sim {
            return Err(ScenarioError::SimConflictsWithElastic { key: self.key() });
        }
        if self.fault.is_kill() && self.transport != TransportAxis::Tcp {
            return Err(ScenarioError::FaultNeedsTcp { key: self.key(), fault: self.fault });
        }
        Ok(())
    }
}

/// One sub-matrix: the cartesian product of its axis lists. Empty axis
/// lists fall back to the single-default entry, so a block only names
/// the axes it sweeps.
#[derive(Clone, Debug)]
pub struct ScenarioBlock {
    pub models: Vec<String>,
    pub regions: Vec<usize>,
    pub transports: Vec<TransportAxis>,
    pub faults: Vec<FaultAxis>,
    pub sparsities: Vec<SparsityAxis>,
    pub seeds: Vec<u64>,
    pub steps: u64,
}

impl Default for ScenarioBlock {
    fn default() -> ScenarioBlock {
        ScenarioBlock {
            models: vec!["syn-xs".into()],
            regions: vec![1],
            transports: vec![TransportAxis::InProc],
            faults: vec![FaultAxis::None],
            sparsities: vec![SparsityAxis::Default],
            seeds: vec![0],
            steps: 4,
        }
    }
}

impl ScenarioBlock {
    fn cells(&self) -> Vec<Scenario> {
        let mut out = Vec::new();
        for model in &self.models {
            for &regions in &self.regions {
                for &transport in &self.transports {
                    for &fault in &self.faults {
                        for &sparsity in &self.sparsities {
                            for &seed in &self.seeds {
                                out.push(Scenario {
                                    model: model.clone(),
                                    regions,
                                    transport,
                                    fault,
                                    sparsity,
                                    seed,
                                    steps: self.steps,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// A named list of scenario blocks — built in (`smoke`, `full`) or
/// loaded from a JSON file (`bench run --file scenarios.json`).
#[derive(Clone, Debug)]
pub struct Suite {
    pub name: String,
    pub blocks: Vec<ScenarioBlock>,
}

pub const SUITE_NAMES: [&str; 2] = ["smoke", "full"];

/// The built-in suites. `smoke` is the CI regression gate: 9 cells in
/// well under a minute, spanning all three transports, two region
/// counts, and three fault kinds. `full` adds the larger models, all
/// four region counts, preemption, and extra seeds.
pub fn builtin_suite(name: &str) -> Option<Suite> {
    let d = ScenarioBlock::default;
    let blocks = match name {
        "smoke" => vec![
            // Transport sweep on the flat fleet.
            ScenarioBlock {
                transports: vec![TransportAxis::InProc, TransportAxis::Tcp],
                ..d()
            },
            // Elastic membership (join + drain) on inproc.
            ScenarioBlock { faults: vec![FaultAxis::Join, FaultAxis::Drain], ..d() },
            // Lease-driven failover over real sockets.
            ScenarioBlock {
                transports: vec![TransportAxis::Tcp],
                faults: vec![FaultAxis::Crash],
                ..d()
            },
            // Two-region relay tree: inproc relays and netsim arrival order.
            ScenarioBlock {
                regions: vec![2],
                transports: vec![TransportAxis::InProc, TransportAxis::Sim],
                ..d()
            },
            // Sparse regime on the bigger small model.
            ScenarioBlock {
                models: vec!["syn-s".into()],
                sparsities: vec![SparsityAxis::Sparse],
                ..d()
            },
            // Seed independence witness on netsim.
            ScenarioBlock { transports: vec![TransportAxis::Sim], seeds: vec![1], ..d() },
        ],
        "full" => vec![
            // Model × sparsity grid.
            ScenarioBlock {
                models: BENCH_MODEL_NAMES.iter().map(|s| s.to_string()).collect(),
                sparsities: SparsityAxis::ALL.to_vec(),
                steps: 6,
                ..d()
            },
            // Region scaling 1..=4 on both relay-capable transports.
            ScenarioBlock {
                models: vec!["syn-s".into()],
                regions: vec![1, 2, 3, 4],
                transports: vec![TransportAxis::InProc, TransportAxis::Sim],
                steps: 6,
                ..d()
            },
            // Full fault battery over real sockets.
            ScenarioBlock {
                models: vec!["syn-s".into()],
                transports: vec![TransportAxis::Tcp],
                faults: vec![FaultAxis::None, FaultAxis::Crash, FaultAxis::Preempt],
                steps: 6,
                ..d()
            },
            // Elastic membership on the mid model.
            ScenarioBlock {
                models: vec!["syn-s".into()],
                faults: vec![FaultAxis::Join, FaultAxis::Drain],
                steps: 6,
                ..d()
            },
            // Extra seeds (seed 0 already covered by the grid block).
            ScenarioBlock { seeds: vec![1, 2], steps: 6, ..d() },
        ],
        _ => return None,
    };
    Some(Suite { name: name.to_string(), blocks })
}

impl Suite {
    /// Expand every block to the validated, duplicate-free cell list.
    pub fn expand(&self) -> Result<Vec<Scenario>, ScenarioError> {
        let mut cells = Vec::new();
        let mut seen = BTreeSet::new();
        for block in &self.blocks {
            for sc in block.cells() {
                sc.validate()?;
                if !seen.insert(sc.key()) {
                    return Err(ScenarioError::DuplicateKey(sc.key()));
                }
                cells.push(sc);
            }
        }
        if cells.is_empty() {
            return Err(ScenarioError::EmptyMatrix);
        }
        Ok(cells)
    }

    /// Load a custom suite from its JSON form:
    ///
    /// ```json
    /// {"suite": "mine", "blocks": [
    ///   {"models": ["syn-xs"], "regions": [1, 2],
    ///    "transports": ["inproc", "sim"], "faults": ["none"],
    ///    "sparsities": ["default"], "seeds": [0], "steps": 4}
    /// ]}
    /// ```
    ///
    /// Omitted axes take the block defaults (syn-xs / r1 / inproc /
    /// none / default / seed 0 / 4 steps).
    pub fn from_json(s: &str) -> Result<Suite, ScenarioError> {
        let j = Json::parse(s).map_err(ScenarioError::Parse)?;
        let name = j
            .get("suite")
            .and_then(Json::as_str)
            .ok_or_else(|| ScenarioError::Parse("missing string \"suite\"".into()))?
            .to_string();
        let blocks_json = j
            .get("blocks")
            .and_then(Json::as_arr)
            .ok_or_else(|| ScenarioError::Parse("missing \"blocks\" array".into()))?;
        let mut blocks = Vec::new();
        for bj in blocks_json {
            let mut b = ScenarioBlock::default();
            if let Some(xs) = bj.get("models").and_then(Json::as_arr) {
                b.models = strings(xs, "models")?;
            }
            if let Some(xs) = bj.get("regions").and_then(Json::as_arr) {
                b.regions = xs
                    .iter()
                    .map(|x| x.as_u64().map(|r| r as usize))
                    .collect::<Option<Vec<_>>>()
                    .ok_or_else(|| ScenarioError::Parse("\"regions\" must be integers".into()))?;
            }
            if let Some(xs) = bj.get("transports").and_then(Json::as_arr) {
                b.transports = strings(xs, "transports")?
                    .into_iter()
                    .map(|s| TransportAxis::parse(&s).ok_or(ScenarioError::UnknownTransport(s)))
                    .collect::<Result<_, _>>()?;
            }
            if let Some(xs) = bj.get("faults").and_then(Json::as_arr) {
                b.faults = strings(xs, "faults")?
                    .into_iter()
                    .map(|s| FaultAxis::parse(&s).ok_or(ScenarioError::UnknownFault(s)))
                    .collect::<Result<_, _>>()?;
            }
            if let Some(xs) = bj.get("sparsities").and_then(Json::as_arr) {
                b.sparsities = strings(xs, "sparsities")?
                    .into_iter()
                    .map(|s| SparsityAxis::parse(&s).ok_or(ScenarioError::UnknownSparsity(s)))
                    .collect::<Result<_, _>>()?;
            }
            if let Some(xs) = bj.get("seeds").and_then(Json::as_arr) {
                b.seeds = xs
                    .iter()
                    .map(Json::as_u64)
                    .collect::<Option<Vec<_>>>()
                    .ok_or_else(|| ScenarioError::Parse("\"seeds\" must be integers".into()))?;
            }
            if let Some(s) = bj.get("steps").and_then(Json::as_u64) {
                b.steps = s;
            }
            blocks.push(b);
        }
        Ok(Suite { name, blocks })
    }
}

fn strings(xs: &[Json], field: &str) -> Result<Vec<String>, ScenarioError> {
    xs.iter()
        .map(|x| x.as_str().map(str::to_string))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| ScenarioError::Parse(format!("\"{field}\" must be strings")))
}

/// A scenario matrix that cannot run — every way a suite is rejected
/// before its first cell executes (the `SpecError` discipline applied to
/// the bench surface).
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioError {
    UnknownModel(String),
    UnknownTransport(String),
    UnknownFault(String),
    UnknownSparsity(String),
    RegionsOutOfRange { regions: usize },
    ZeroSteps,
    TooFewStepsForFault { key: String, steps: u64 },
    /// The sim fleet is fixed at topology-build time; join/drain need a
    /// live membership plane (inproc or tcp).
    SimConflictsWithElastic { key: String },
    /// Crash/preempt slam a real socket; only the Tcp backend has one.
    FaultNeedsTcp { key: String, fault: FaultAxis },
    /// Multi-region runs use the relay tree (inproc) or netsim; Tcp
    /// streams hub→actor directly (mirrors `SpecError` wan×tcp).
    WanConflictsWithTcp { key: String },
    /// Fault pins target the flat fleet's fixed actor ids; the wan
    /// presets own their fleet shape (mirrors `SpecError` wan×elastic).
    WanConflictsWithFault { key: String },
    EmptyMatrix,
    DuplicateKey(String),
    /// Suite-file JSON that does not parse into blocks.
    Parse(String),
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScenarioError::UnknownModel(m) => {
                write!(f, "unknown bench model {m:?} (known: {})", BENCH_MODEL_NAMES.join(", "))
            }
            ScenarioError::UnknownTransport(t) => {
                write!(f, "unknown transport {t:?} (inproc|sim|tcp)")
            }
            ScenarioError::UnknownFault(x) => {
                write!(f, "unknown fault {x:?} (none|join|drain|crash|preempt)")
            }
            ScenarioError::UnknownSparsity(x) => {
                write!(f, "unknown sparsity regime {x:?} (dense|default|sparse)")
            }
            ScenarioError::RegionsOutOfRange { regions } => {
                write!(f, "regions = {regions}, but the wan presets cover 1..=4")
            }
            ScenarioError::ZeroSteps => write!(f, "steps must be >= 1"),
            ScenarioError::TooFewStepsForFault { key, steps } => write!(
                f,
                "{key}: fault pins land at version steps-2, so faulted cells need >= 3 \
                 steps (got {steps})"
            ),
            ScenarioError::SimConflictsWithElastic { key } => write!(
                f,
                "{key}: the sim fleet is fixed at topology-build time; join/drain need \
                 inproc or tcp"
            ),
            ScenarioError::FaultNeedsTcp { key, fault } => write!(
                f,
                "{key}: {} fault injection kills a real socket; use the tcp transport",
                fault.name()
            ),
            ScenarioError::WanConflictsWithTcp { key } => write!(
                f,
                "{key}: multi-region cells run the relay tree (inproc) or netsim; tcp \
                 streams hub→actor directly"
            ),
            ScenarioError::WanConflictsWithFault { key } => write!(
                f,
                "{key}: fault cells run on the flat single-region fleet (the wan presets \
                 fix their own fleet shape)"
            ),
            ScenarioError::EmptyMatrix => {
                write!(f, "the suite expands to zero scenario cells")
            }
            ScenarioError::DuplicateKey(k) => {
                write!(f, "duplicate scenario key {k} (blocks overlap)")
            }
            ScenarioError::Parse(e) => write!(f, "suite file: {e}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_canonical_and_stable() {
        let sc = Scenario {
            model: "syn-xs".into(),
            regions: 2,
            transport: TransportAxis::Sim,
            fault: FaultAxis::None,
            sparsity: SparsityAxis::Sparse,
            seed: 7,
            steps: 4,
        };
        assert_eq!(sc.key(), "syn-xs/r2/sim/none/sparse/seed7");
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn builtin_suites_expand_cleanly() {
        for name in SUITE_NAMES {
            let suite = builtin_suite(name).unwrap();
            let cells = suite.expand().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!cells.is_empty());
        }
        assert!(builtin_suite("nope").is_none());
    }

    #[test]
    fn suite_json_round_trip_with_defaults() {
        let suite = Suite::from_json(
            r#"{"suite":"mine","blocks":[{"regions":[1,2],"transports":["inproc","sim"]}]}"#,
        )
        .unwrap();
        assert_eq!(suite.name, "mine");
        let cells = suite.expand().unwrap();
        assert_eq!(cells.len(), 4);
        assert!(cells.iter().all(|c| c.model == "syn-xs" && c.steps == 4));
    }
}
