//! Scenario execution: one validated [`Scenario`] in, one
//! [`ResultRecord`] out, through the public `Session`/`RunSpec` API on
//! [`SyntheticCompute`].
//!
//! Every cell runs `--deterministic`, so the gated metrics (payload and
//! dense bytes, rho, gen tokens, membership counts) and the SHA-256
//! policy witness are bit-stable across replays and machines; wall-clock
//! metrics (makespan, overlap, tok/s, tok/$) ride along as ungated
//! gauges. Fault pins land at version `steps - 2` — the final step —
//! where `tests/transport_fault.rs` proves a faulted run still matches
//! the healthy baseline bitwise.

use crate::bench::scenario::{bench_model, FaultAxis, Scenario};
use crate::bench::summary::{Better, ResultRecord, ResultSet};
use crate::cost;
use crate::metrics::SpanKind;
use crate::rt::{BootstrapKind, SyntheticCompute};
use crate::session::{Backend, RunSpec, Session};
use crate::transport::{KillMode, KillSpec, TcpConfig};
use anyhow::{anyhow, Context, Result};
use std::time::Duration;

/// Single-region cells run this flat fleet; the join cell adds actor
/// `FLAT_FLEET` (ids are contiguous), drain/crash/preempt target actor
/// `FLAT_FLEET - 1`.
pub const FLAT_FLEET: usize = 3;

/// Actors per region under the `wan-N` presets (`config::wan_preset`).
const ACTORS_PER_REGION: usize = 2;

/// Emulated accelerator latencies: small enough to keep the smoke suite
/// fast, large enough that overlap/makespan gauges measure something.
const TRAIN_DELAY: Duration = Duration::from_millis(4);
const GEN_DELAY: Duration = Duration::from_millis(3);

/// The version every fault pin fires at: the run's final step, the
/// strongest determinism point (see `tests/transport_fault.rs`).
fn fault_pin(steps: u64) -> u64 {
    steps - 2
}

/// Translate one scenario cell into a `RunSpec` (kill scripts, when the
/// fault calls for one, ride inside the `Backend::Tcp` config).
fn spec_for(sc: &Scenario) -> RunSpec {
    let mut spec = RunSpec::synthetic()
        .steps(sc.steps)
        .sft_steps(0)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .segment_bytes(4 << 10)
        .seed(sc.seed)
        .deterministic()
        .pipelined();
    if sc.regions == 1 {
        spec = spec.actors(FLAT_FLEET);
    } else {
        spec = spec.wan(&format!("wan-{}", sc.regions));
    }
    let pin = fault_pin(sc.steps);
    let mut kills = Vec::new();
    match sc.fault {
        FaultAxis::None => {}
        FaultAxis::Join => {
            spec = spec.join_at(FLAT_FLEET as u32, pin, BootstrapKind::DeltaChain);
        }
        FaultAxis::Drain => {
            spec = spec.leave_at(FLAT_FLEET as u32 - 1, pin);
        }
        FaultAxis::Crash => {
            spec = spec.wall_leases();
            kills.push(KillSpec {
                actor: FLAT_FLEET as u32 - 1,
                at_version: pin,
                mode: KillMode::Crash,
            });
        }
        FaultAxis::Preempt => {
            spec = spec.wall_leases();
            kills.push(KillSpec {
                actor: FLAT_FLEET as u32 - 1,
                at_version: pin,
                mode: KillMode::Preempt { warn_ms: 0 },
            });
        }
    }
    let backend = match sc.transport {
        crate::bench::scenario::TransportAxis::InProc => Backend::InProc,
        crate::bench::scenario::TransportAxis::Sim => Backend::Sim,
        crate::bench::scenario::TransportAxis::Tcp => {
            Backend::Tcp(TcpConfig { kills: std::mem::take(&mut kills), ..TcpConfig::default() })
        }
    };
    spec.transport(backend)
}

/// Run one cell and fold its report into the harness record.
pub fn run_scenario(sc: &Scenario) -> Result<ResultRecord> {
    sc.validate().map_err(|e| anyhow!("invalid scenario: {e}"))?;
    let model = bench_model(&sc.model).expect("validate() checked the model preset");
    let comp = SyntheticCompute::new(model.b_train, model.b_gen, model.max_seq)
        .with_update_divisor(sc.sparsity.update_divisor())
        .with_delays(TRAIN_DELAY, GEN_DELAY);
    let plan = spec_for(sc).build().map_err(|e| anyhow!("scenario {}: {e}", sc.key()))?;
    let report = Session::start_with_compute(&plan, model.layout.clone(), comp)
        .and_then(Session::join)
        .with_context(|| format!("scenario {}", sc.key()))?;

    let n_steps = report.steps.len().max(1) as u64;
    let payload: u64 = report.steps.iter().map(|s| s.payload_bytes).sum();
    let dense: u64 = report.steps.iter().map(|s| s.dense_bytes).sum();
    let gen_tokens: u64 = report.steps.iter().map(|s| s.gen_tokens).sum();
    let overlap =
        report.timeline.overlap_ratio("trainer", &[SpanKind::Train, SpanKind::Extract]);
    let tok_per_s = gen_tokens as f64 / report.wall_s.max(1e-9);
    // Cost gauge: price the cell as the matching cross-cloud deployment
    // shipping one relay copy per region per step.
    let actors_per_region = if sc.regions == 1 { FLAT_FLEET } else { ACTORS_PER_REGION };
    let deployment = cost::wan_deployment(sc.regions, actors_per_region);
    let tok_per_dollar = deployment.tokens_per_dollar_with_egress(
        tok_per_s,
        (payload / n_steps) * sc.regions as u64,
        report.wall_s.max(1e-9) / n_steps as f64,
    );

    let mut rec = ResultRecord::new(&sc.key())
        .axis("model", &sc.model)
        .axis("regions", &sc.regions.to_string())
        .axis("transport", sc.transport.name())
        .axis("fault", sc.fault.name())
        .axis("sparsity", sc.sparsity.name())
        .axis("seed", &sc.seed.to_string())
        .axis("steps", &sc.steps.to_string())
        // Deterministic, gated: the regression surface.
        .gate("payload_bytes", payload as f64, Better::Lower)
        .gate("dense_bytes", dense as f64, Better::Lower)
        .gate("rho", report.mean_rho(), Better::Lower)
        .gate("gen_tokens", gen_tokens as f64, Better::Exact)
        .gate("final_version", report.final_version as f64, Better::Exact)
        .gate("failovers", report.failovers as f64, Better::Exact)
        .gate("requeued_prompts", report.requeued_prompts as f64, Better::Exact)
        .gate("joins", report.joins as f64, Better::Exact)
        .gate("drains", report.drains as f64, Better::Exact)
        .gate("preempts", report.preempts as f64, Better::Exact)
        // Machine-dependent, informational.
        .gauge("makespan_s", report.wall_s)
        .gauge("overlap_ratio", overlap)
        .gauge("tok_per_s", tok_per_s)
        .gauge("tok_per_dollar", tok_per_dollar);
    if let Some(last) = report.steps.last() {
        rec = rec.with_witness(&last.checksum_hex());
    }
    Ok(rec)
}

/// Run every cell of an expanded suite into one [`ResultSet`]. A cell
/// that fails to run aborts the suite (structural illegality was already
/// rejected at expansion, so a failure here is a real runtime bug).
pub fn run_suite(suite: &str, cells: &[Scenario]) -> Result<ResultSet> {
    let mut set = ResultSet::new(suite);
    for (i, sc) in cells.iter().enumerate() {
        println!("[{}/{}] {}", i + 1, cells.len(), sc.key());
        let rec = run_scenario(sc)?;
        let payload = rec.metrics.get("payload_bytes").map_or(0.0, |m| m.value);
        let rho = rec.metrics.get("rho").map_or(0.0, |m| m.value);
        println!(
            "        payload {}  rho {:.4}%  witness {}",
            crate::util::fmt_bytes(payload as u64),
            rho * 100.0,
            rec.witness.as_deref().map(|w| &w[..12.min(w.len())]).unwrap_or("-"),
        );
        set.push(rec);
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::scenario::{SparsityAxis, TransportAxis};

    fn cell(fault: FaultAxis, transport: TransportAxis) -> Scenario {
        Scenario {
            model: "syn-xs".into(),
            regions: 1,
            transport,
            fault,
            sparsity: SparsityAxis::Default,
            seed: 0,
            steps: 3,
        }
    }

    #[test]
    fn one_cell_produces_a_gated_record_with_witness() {
        let rec = run_scenario(&cell(FaultAxis::None, TransportAxis::InProc)).unwrap();
        assert_eq!(rec.key, "syn-xs/r1/inproc/none/default/seed0");
        assert!(rec.metrics["payload_bytes"].gated);
        assert!(rec.metrics["payload_bytes"].value > 0.0);
        assert!(!rec.metrics["makespan_s"].gated);
        let w = rec.witness.as_deref().expect("deterministic run has a witness");
        assert_eq!(w.len(), 64, "SHA-256 hex");
        assert_eq!(rec.metrics["final_version"].value, 3.0);
    }

    #[test]
    fn join_cell_counts_one_join_and_matches_axes() {
        let rec = run_scenario(&cell(FaultAxis::Join, TransportAxis::InProc)).unwrap();
        assert_eq!(rec.metrics["joins"].value, 1.0);
        assert_eq!(rec.axes["fault"], "join");
    }

    #[test]
    fn invalid_cell_is_rejected_before_running() {
        let sc = cell(FaultAxis::Crash, TransportAxis::InProc);
        assert!(run_scenario(&sc).is_err(), "crash needs tcp; must fail fast");
    }
}
