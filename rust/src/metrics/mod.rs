//! Metrics: step timelines (the Figure 9 Gantt trace), throughput
//! accounting, and JSONL export.

use crate::util::jsonl::Json;

/// What a span of time was spent on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Rollout,
    Train,
    Extract,
    Transfer,
    Commit,
    Idle,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Rollout => "rollout",
            SpanKind::Train => "train",
            SpanKind::Extract => "extract",
            SpanKind::Transfer => "transfer",
            SpanKind::Commit => "commit",
            SpanKind::Idle => "idle",
        }
    }
}

/// One timeline span (entity = "trainer", "actor3", "relay:canada", ...).
#[derive(Clone, Debug)]
pub struct Span {
    pub entity: String,
    pub kind: SpanKind,
    pub start: f64,
    pub end: f64,
    pub step: u64,
}

/// Execution timeline for a run (Figure 9's raw data).
#[derive(Default, Clone, Debug)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn record(&mut self, entity: &str, kind: SpanKind, start: f64, end: f64, step: u64) {
        debug_assert!(end >= start, "span ends before it starts");
        self.spans.push(Span { entity: entity.to_string(), kind, start, end, step });
    }

    /// Total time an entity spent in `kind`.
    pub fn total(&self, entity: &str, kind: SpanKind) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.entity == entity && s.kind == kind)
            .map(|s| s.end - s.start)
            .sum()
    }

    pub fn end_time(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Merge the spans of `kind` recorded by entities *other than*
    /// `exclude` into a sorted union of disjoint intervals.
    fn merged_windows(&self, exclude: &str, kind: SpanKind) -> Vec<(f64, f64)> {
        let mut iv: Vec<(f64, f64)> = self
            .spans
            .iter()
            .filter(|s| s.entity != exclude && s.kind == kind && s.end > s.start)
            .map(|s| (s.start, s.end))
            .collect();
        iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
        for (a, b) in iv {
            match out.last_mut() {
                Some(last) if a <= last.1 => last.1 = last.1.max(b),
                _ => out.push((a, b)),
            }
        }
        out
    }

    /// Fraction of `entity`'s time in `kinds` that other entities covered
    /// with Rollout spans — the paper's pipelining metric: how much of the
    /// synchronization path (train / extract / transfer) was *hidden*
    /// inside the generation window. 0.0 for a strictly sequential run,
    /// approaching 1.0 when sync is fully off the critical path.
    pub fn overlap_ratio(&self, entity: &str, kinds: &[SpanKind]) -> f64 {
        let windows = self.merged_windows(entity, SpanKind::Rollout);
        let mut sync = 0.0;
        let mut hidden = 0.0;
        for s in self
            .spans
            .iter()
            .filter(|s| s.entity == entity && kinds.contains(&s.kind))
        {
            sync += s.end - s.start;
            for &(a, b) in &windows {
                let lo = s.start.max(a);
                let hi = s.end.min(b);
                if hi > lo {
                    hidden += hi - lo;
                }
            }
        }
        if sync <= 0.0 {
            0.0
        } else {
            hidden / sync
        }
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for s in &self.spans {
            let j = Json::obj()
                .set("entity", s.entity.as_str())
                .set("kind", s.kind.name())
                .set("start", s.start)
                .set("end", s.end)
                .set("step", s.step);
            out.push_str(&j.to_string());
            out.push('\n');
        }
        out
    }

    /// Render an ASCII Gantt chart (the Figure 9 view), `width` cols wide.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let end = self.end_time().max(1e-9);
        let mut entities: Vec<String> = self.spans.iter().map(|s| s.entity.clone()).collect();
        entities.sort();
        entities.dedup();
        let mut out = String::new();
        for e in &entities {
            let mut row = vec![' '; width];
            for s in self.spans.iter().filter(|s| &s.entity == e) {
                let a = ((s.start / end) * width as f64) as usize;
                let b = (((s.end / end) * width as f64).ceil() as usize).min(width);
                let c = match s.kind {
                    SpanKind::Rollout => 'R',
                    SpanKind::Train => 'T',
                    SpanKind::Extract => 'E',
                    SpanKind::Transfer => '=',
                    SpanKind::Commit => '|',
                    SpanKind::Idle => '.',
                };
                for slot in row.iter_mut().take(b).skip(a.min(width)) {
                    *slot = c;
                }
            }
            out.push_str(&format!("{:<16} {}\n", e, row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "{:<16} 0{}{:.0}s\n",
            "",
            " ".repeat(width.saturating_sub(6)),
            end
        ));
        out
    }
}

/// Token-throughput accumulator (the paper's primary metric: "average
/// number of tokens processed per second across the entire system").
#[derive(Default, Clone, Copy, Debug)]
pub struct Throughput {
    pub tokens: u64,
    pub elapsed: f64,
}

impl Throughput {
    pub fn add(&mut self, tokens: u64) {
        self.tokens += tokens;
    }

    pub fn finish(&mut self, elapsed: f64) {
        self.elapsed = elapsed;
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.elapsed <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.elapsed
        }
    }
}

/// Geometric mean (Table 6 aggregates throughput across benchmarks).
pub fn geometric_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_by_entity_and_kind() {
        let mut t = Timeline::default();
        t.record("trainer", SpanKind::Train, 0.0, 5.0, 1);
        t.record("trainer", SpanKind::Extract, 5.0, 6.0, 1);
        t.record("actor0", SpanKind::Rollout, 0.0, 8.0, 1);
        t.record("trainer", SpanKind::Train, 8.0, 12.0, 2);
        assert_eq!(t.total("trainer", SpanKind::Train), 9.0);
        assert_eq!(t.total("actor0", SpanKind::Rollout), 8.0);
        assert_eq!(t.end_time(), 12.0);
    }

    #[test]
    fn jsonl_one_line_per_span() {
        let mut t = Timeline::default();
        t.record("a", SpanKind::Transfer, 0.0, 1.5, 3);
        let s = t.to_jsonl();
        assert_eq!(s.lines().count(), 1);
        assert!(s.contains("\"kind\":\"transfer\""));
        assert!(s.contains("\"step\":3"));
    }

    #[test]
    fn gantt_renders_all_entities() {
        let mut t = Timeline::default();
        t.record("trainer", SpanKind::Train, 0.0, 4.0, 1);
        t.record("actor0", SpanKind::Rollout, 1.0, 8.0, 1);
        let g = t.ascii_gantt(40);
        assert!(g.contains("trainer"));
        assert!(g.contains("actor0"));
        assert!(g.contains('T'));
        assert!(g.contains('R'));
    }

    #[test]
    fn overlap_ratio_measures_hidden_sync_time() {
        let mut t = Timeline::default();
        // Two actors generate 0-10 and 2-6; trainer syncs 4-8 (train) and
        // 8-12 (extract). Rollout union = [0,10]; hidden = 4 + 2 of 8.
        t.record("actor0", SpanKind::Rollout, 0.0, 10.0, 1);
        t.record("actor1", SpanKind::Rollout, 2.0, 6.0, 1);
        t.record("trainer", SpanKind::Train, 4.0, 8.0, 1);
        t.record("trainer", SpanKind::Extract, 8.0, 12.0, 1);
        let r = t.overlap_ratio("trainer", &[SpanKind::Train, SpanKind::Extract]);
        assert!((r - 0.75).abs() < 1e-9, "r={r}");
        // A strictly sequential trace hides nothing.
        let mut seq = Timeline::default();
        seq.record("actor0", SpanKind::Rollout, 0.0, 5.0, 1);
        seq.record("trainer", SpanKind::Train, 5.0, 9.0, 1);
        assert_eq!(seq.overlap_ratio("trainer", &[SpanKind::Train]), 0.0);
        // No sync spans at all: defined as 0.
        assert_eq!(seq.overlap_ratio("trainer", &[SpanKind::Commit]), 0.0);
    }

    #[test]
    fn throughput_math() {
        let mut th = Throughput::default();
        th.add(500);
        th.add(1500);
        th.finish(4.0);
        assert_eq!(th.tokens_per_s(), 500.0);
    }

    #[test]
    fn geometric_mean_basic() {
        assert!((geometric_mean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geometric_mean(&[5.0, 5.0, 5.0]) - 5.0).abs() < 1e-9);
        assert_eq!(geometric_mean(&[]), 0.0);
    }
}
