//! WAN substrate: analytic link models + a small discrete-event queue.
//!
//! The paper's testbed is real cross-cloud WAN (US↔Canada/Japan/NL/Iceland/
//! Australia) plus `tc`-emulated bandwidth sweeps (§7.4). We do not have a
//! WAN, so this module *is* the substitution (DESIGN.md §3): links are
//! parameterized by exactly the quantities `tc` controls — capacity, RTT,
//! loss — plus a jitter term for cross-cloud fluctuation, and TCP behaviour
//! is modelled with the Mathis throughput ceiling, which captures the two
//! phenomena the paper exploits: a single stream under-utilizes a long-fat
//! lossy pipe, and S parallel streams recover up to the capacity limit.
//!
//! Three layers:
//! * [`link`] — analytic per-path throughput (Mathis ceiling, slow-start,
//!   jitter);
//! * [`event`] — a deterministic discrete-event queue over virtual time;
//! * [`stripes`] — segment-level arrival order under multi-stream
//!   striping: heterogeneous WAN legs are loss-free at this layer but
//!   reorder freely across stripes, which is exactly what the staging
//!   decoders must tolerate.

pub mod event;
pub mod link;
pub mod stripes;

pub use event::EventQueue;
pub use link::{Link, TransferOpts};
pub use stripes::{deliver_striped, striped_makespan, Arrival};

/// Simulated time in seconds.
pub type SimTime = f64;
