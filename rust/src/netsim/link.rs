//! Analytic WAN link model.
//!
//! Effective throughput of one TCP stream on a lossy long-RTT path follows
//! the Mathis et al. ceiling  `T = MSS·C / (RTT·√p)`; `S` parallel streams
//! scale that ceiling until the path capacity (times a protocol-efficiency
//! factor) caps it. Transfers additionally pay connection latency and a
//! slow-start ramp. Cross-cloud capacity fluctuates (paper: 0.5–1 Gbps on
//! US-Canada), modelled as a per-transfer multiplicative jitter factor.

use crate::config::RegionProfile;
use crate::util::Rng;

/// TCP maximum segment size (bytes) used by the Mathis model.
pub const MSS_BYTES: f64 = 1460.0;
/// Mathis constant for delayed-ACK Reno-family flows.
pub const MATHIS_C: f64 = 1.22;
/// Fraction of raw capacity achievable by bulk TCP (framing + CC dynamics).
pub const PROTOCOL_EFFICIENCY: f64 = 0.80;

/// Options for a modelled transfer.
#[derive(Clone, Copy, Debug)]
pub struct TransferOpts {
    /// Parallel TCP streams striped over (§5.2).
    pub streams: usize,
    /// Sample capacity jitter for this transfer (off = deterministic mean).
    pub jittered: bool,
}

impl Default for TransferOpts {
    fn default() -> Self {
        TransferOpts { streams: 1, jittered: false }
    }
}

/// A point-to-point WAN path between the Trainer and one region (or
/// between a Relay and its peers).
#[derive(Clone, Debug)]
pub struct Link {
    pub name: String,
    /// Nominal bottleneck capacity, bits/s.
    pub capacity_bps: f64,
    pub rtt_s: f64,
    pub loss: f64,
    pub jitter: f64,
}

impl Link {
    pub fn from_profile(p: &RegionProfile) -> Link {
        Link {
            name: p.name.to_string(),
            capacity_bps: p.bandwidth_bps,
            rtt_s: p.rtt_s,
            loss: p.loss,
            jitter: p.jitter,
        }
    }

    /// A clean link with explicit parameters (tc-style emulation, §7.4).
    pub fn emulated(capacity_bps: f64, rtt_s: f64, loss: f64) -> Link {
        Link {
            name: format!("tc-{:.0}mbps", capacity_bps / 1e6),
            capacity_bps,
            rtt_s,
            loss,
            jitter: 0.0,
        }
    }

    /// Mathis ceiling for a single TCP stream on this path, bits/s.
    pub fn single_stream_ceiling_bps(&self) -> f64 {
        if self.loss <= 0.0 {
            return self.capacity_bps * PROTOCOL_EFFICIENCY;
        }
        let mathis = MSS_BYTES * 8.0 * MATHIS_C / (self.rtt_s * self.loss.sqrt());
        mathis.min(self.capacity_bps * PROTOCOL_EFFICIENCY)
    }

    /// Aggregate effective throughput for `s` parallel streams, bits/s.
    pub fn effective_bps(&self, s: usize) -> f64 {
        let per_stream = self.single_stream_ceiling_bps();
        (per_stream * s.max(1) as f64).min(self.capacity_bps * PROTOCOL_EFFICIENCY)
    }

    /// Capacity multiplier sampled for one transfer (cross-cloud
    /// fluctuation). Mean 1.0, clamped to [0.5, 1.5].
    pub fn jitter_factor(&self, rng: &mut Rng) -> f64 {
        if self.jitter <= 0.0 {
            return 1.0;
        }
        (1.0 + self.jitter * rng.normal()).clamp(0.5, 1.5)
    }

    /// Wall time to move `bytes` over this path as one blocking transfer.
    pub fn transfer_time(&self, bytes: u64, opts: TransferOpts, rng: &mut Rng) -> f64 {
        let jf = if opts.jittered { self.jitter_factor(rng) } else { 1.0 };
        let bw = self.effective_bps(opts.streams) * jf;
        self.startup_time() + bytes as f64 * 8.0 / bw
    }

    /// Handshake + slow-start ramp cost: one RTT handshake plus roughly
    /// log2(BDP/IW) RTTs to open the window, capped for sanity.
    pub fn startup_time(&self) -> f64 {
        let bdp_segments =
            (self.effective_bps(1) * self.rtt_s / (MSS_BYTES * 8.0)).max(1.0);
        let ramp_rtts = (bdp_segments / 10.0).log2().clamp(0.0, 10.0);
        self.rtt_s * (1.0 + ramp_rtts)
    }

    /// One-way propagation latency for small control messages (§2.3 C1's
    /// "small control messages pay WAN RTT" cost).
    pub fn control_delay(&self) -> f64 {
        self.rtt_s / 2.0
    }

    /// Completion time of a transfer whose source *produces* the bytes at
    /// `produce_bps` while segments of `segment_bytes` are forwarded
    /// cut-through over the link (§5.2's pipelined extraction/transfer).
    ///
    /// Classic two-stage pipeline bound: with k segments of size s,
    /// completion = startup + max( s/Re + B/Rn , B/Re + s/Rn ) where Re/Rn
    /// are extract/network byte rates.
    pub fn pipelined_time(
        &self,
        bytes: u64,
        produce_bps: f64,
        segment_bytes: u64,
        opts: TransferOpts,
        rng: &mut Rng,
    ) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let jf = if opts.jittered { self.jitter_factor(rng) } else { 1.0 };
        let rn = self.effective_bps(opts.streams) * jf; // bits/s
        let re = produce_bps;
        let b = bytes as f64 * 8.0;
        let s = (segment_bytes as f64 * 8.0).min(b);
        let stage = (s / re + b / rn).max(b / re + s / rn);
        self.startup_time() + stage
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::regions;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn calibration_us_canada_single_stream() {
        // Paper §7.3: 202 MB over US-Canada, single TCP = 4.71 s.
        let link = Link::from_profile(&regions::CANADA);
        let t = link.transfer_time(202_000_000, TransferOpts::default(), &mut rng());
        assert!(
            (3.8..5.8).contains(&t),
            "single-stream 202MB took {t:.2} s (paper: 4.71 s)"
        );
    }

    #[test]
    fn calibration_us_canada_multi_stream() {
        // Paper §7.3: 4 streams cut 4.71 s to 2.90 s.
        let link = Link::from_profile(&regions::CANADA);
        let t1 = link.transfer_time(202_000_000, TransferOpts { streams: 1, jittered: false }, &mut rng());
        let t4 = link.transfer_time(202_000_000, TransferOpts { streams: 4, jittered: false }, &mut rng());
        assert!((2.3..3.6).contains(&t4), "4-stream took {t4:.2} s (paper: 2.90 s)");
        assert!(t4 < t1, "multi-stream must help");
        let speedup = t1 / t4;
        assert!((1.2..2.2).contains(&speedup), "speedup {speedup:.2}");
    }

    #[test]
    fn full_weight_sync_matches_table2() {
        // Table 2: 16 GB over 1 Gbps commodity link = 128 s; over 100 Gbps
        // RDMA = 1.3 s.
        let commodity = Link::emulated(1e9, 0.030, 0.0);
        let t = commodity.transfer_time(16_000_000_000, TransferOpts { streams: 8, jittered: false }, &mut rng());
        assert!((120.0..190.0).contains(&t), "commodity sync {t:.1} s (paper 128 s)");
        let rdma = Link::emulated(100e9, 0.000_05, 0.0);
        let t = rdma.transfer_time(16_000_000_000, TransferOpts { streams: 8, jittered: false }, &mut rng());
        assert!((1.0..2.2).contains(&t), "rdma sync {t:.2} s (paper 1.3 s)");
    }

    #[test]
    fn streams_saturate_at_capacity() {
        let link = Link::from_profile(&regions::CANADA);
        let e1 = link.effective_bps(1);
        let e4 = link.effective_bps(4);
        let e64 = link.effective_bps(64);
        assert!(e4 > e1);
        assert!(e64 <= link.capacity_bps * PROTOCOL_EFFICIENCY + 1.0);
        assert_eq!(e64, link.effective_bps(1024));
    }

    #[test]
    fn lossless_link_hits_protocol_efficiency() {
        let link = Link::emulated(10e9, 0.001, 0.0);
        assert!((link.effective_bps(1) - 8e9).abs() < 1.0);
    }

    #[test]
    fn long_rtt_punishes_single_stream_more() {
        // Cross-continent paths motivate multi-stream (§5.2, Fig 11).
        let near = Link::from_profile(&regions::CANADA);
        let far = Link::from_profile(&regions::AUSTRALIA);
        let near_ratio = near.effective_bps(8) / near.effective_bps(1);
        let far_ratio = far.effective_bps(8) / far.effective_bps(1);
        assert!(far_ratio > near_ratio, "far {far_ratio:.2} vs near {near_ratio:.2}");
    }

    #[test]
    fn pipelining_overlaps_extraction_with_transfer() {
        // Extraction at 3.2 GB/s of a 202 MB delta (paper ~5 s for 16 GB
        // scan but the encode stream emits ~200 MB), link at ~550 Mbps:
        // pipelined completion should be close to max(extract, transfer),
        // far below their sum.
        let link = Link::from_profile(&regions::CANADA);
        let mut r = rng();
        let bytes = 202_000_000u64;
        let extract_bps = 0.4e9 * 8.0; // delta bytes produced per second
        let opts = TransferOpts { streams: 4, jittered: false };
        let serial = bytes as f64 * 8.0 / extract_bps
            + link.transfer_time(bytes, opts, &mut r);
        let pipelined = link.pipelined_time(bytes, extract_bps, 1 << 20, opts, &mut r);
        assert!(pipelined < serial * 0.90, "pipelined {pipelined:.2} vs serial {serial:.2}");
        let transfer_only = link.transfer_time(bytes, opts, &mut r);
        assert!(pipelined >= transfer_only * 0.95);
    }

    #[test]
    fn jitter_is_bounded_and_mean_preserving() {
        let link = Link::from_profile(&regions::CANADA);
        let mut r = rng();
        let mut sum = 0.0;
        for _ in 0..5000 {
            let f = link.jitter_factor(&mut r);
            assert!((0.5..=1.5).contains(&f));
            sum += f;
        }
        let mean: f64 = sum / 5000.0;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zero_bytes_is_free_pipelined() {
        let link = Link::from_profile(&regions::CANADA);
        assert_eq!(
            link.pipelined_time(0, 1e9, 1 << 20, TransferOpts::default(), &mut rng()),
            0.0
        );
    }
}
