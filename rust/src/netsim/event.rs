//! Minimal discrete-event queue over virtual time.
//!
//! The end-to-end simulator (`sim/`) is mostly step-structured arithmetic,
//! but transfer pipelines, lease expiries, and failure injection need
//! fine-grained ordering; this queue provides it. Events carry a typed
//! payload `E`; the driver pops in (time, seq) order — seq breaks ties
//! deterministically in insertion order.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::SimTime;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with a monotonic clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` at absolute time `at` (must be >= now).
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        debug_assert!(at >= self.now, "cannot schedule in the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time: at.max(self.now), seq, payload });
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        self.schedule_at(self.now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.payload))
    }

    /// Peek the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn ties_break_in_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn relative_scheduling_uses_current_clock() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "first");
        q.pop();
        q.schedule_in(5.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    fn clock_is_monotone_under_random_load() {
        prop::check("event queue monotone", 30, |rng| {
            let mut q = EventQueue::new();
            for _ in 0..rng.range(1, 200) {
                q.schedule_at(rng.f64() * 100.0, ());
            }
            let mut last = -1.0;
            // Interleave pops with new future insertions.
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
                if rng.chance(0.3) {
                    q.schedule_in(rng.f64(), ());
                }
                if q.processed() > 1000 {
                    break;
                }
            }
        });
    }
}
