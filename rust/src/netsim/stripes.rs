//! Striped segment delivery over one WAN link: the arrival-order model.
//!
//! The analytic [`Link`] model prices *aggregate* transfer
//! time; this module models what multi-stream transmission does to the
//! *order* segments reach a receiver. Each of `S` stripes is an
//! independent serial pipe (FIFO within a stripe — TCP guarantees that),
//! but stripes progress at independently jittered rates, so arrival order
//! across stripes reorders freely. Links are loss-free at this layer
//! (TCP retransmission is below the segment abstraction): every segment
//! arrives exactly once.
//!
//! Receivers must therefore tolerate arbitrary cross-stripe reordering —
//! the `Reassembler`, the streaming staging decoder, and the commit
//! parking in `actor::PolicyState` are all exercised against arrival
//! orders produced here (see `tests/wan_distribution.rs`).

use super::{EventQueue, Link, SimTime};
use crate::transport::stripe::stream_for;
use crate::util::Rng;

/// Arrival of one striped segment at the receiver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Simulated arrival time, seconds.
    pub at: SimTime,
    /// Index of the segment in the sender's emission order (its seq).
    pub index: usize,
    /// Stripe the segment travelled on.
    pub stripe: usize,
}

/// Simulate delivery of segments with byte sizes `sizes` over `streams`
/// parallel stripes of `link`, returning arrivals in receive order.
///
/// Segment `i` rides stripe `i % streams` (the deterministic
/// [`stream_for`] assignment, so relays can re-stripe without
/// coordination); each stripe serializes its queue at an equal share of
/// the link's effective multi-stream throughput, with per-segment rate
/// jitter sampled from the link's fluctuation model. Within a stripe,
/// arrival order equals send order; across stripes it does not.
pub fn deliver_striped(
    link: &Link,
    sizes: &[u64],
    streams: usize,
    rng: &mut Rng,
) -> Vec<Arrival> {
    let s = streams.max(1);
    let per_stream_bps = (link.effective_bps(s) / s as f64).max(1.0);
    // Per-stripe clock: when the stripe finishes sending its queued bytes.
    let mut clock = vec![link.startup_time(); s];
    let mut q = EventQueue::new();
    for (i, &bytes) in sizes.iter().enumerate() {
        let k = stream_for(i as u32, s);
        let jf = link.jitter_factor(rng);
        clock[k] += bytes as f64 * 8.0 / (per_stream_bps * jf);
        // One-way propagation after the stripe's send completes.
        q.schedule_at(clock[k] + link.rtt_s / 2.0, (i, k));
    }
    let mut out = Vec::with_capacity(sizes.len());
    while let Some((at, (index, stripe))) = q.pop() {
        out.push(Arrival { at, index, stripe });
    }
    out
}

/// Completion time of a striped delivery (the last segment's arrival).
pub fn striped_makespan(link: &Link, sizes: &[u64], streams: usize, rng: &mut Rng) -> SimTime {
    deliver_striped(link, sizes, streams, rng)
        .last()
        .map(|a| a.at)
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::regions;

    fn sizes(n: usize, bytes: u64) -> Vec<u64> {
        vec![bytes; n]
    }

    #[test]
    fn every_segment_arrives_exactly_once() {
        let link = Link::from_profile(&regions::CANADA);
        let mut rng = Rng::new(3);
        let arr = deliver_striped(&link, &sizes(57, 1 << 20), 4, &mut rng);
        let mut idx: Vec<usize> = arr.iter().map(|a| a.index).collect();
        idx.sort_unstable();
        assert_eq!(idx, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn within_stripe_order_preserved_across_stripes_reordered() {
        let link = Link::from_profile(&regions::CANADA); // jitter 0.18
        let mut rng = Rng::new(7);
        let arr = deliver_striped(&link, &sizes(64, 1 << 20), 4, &mut rng);
        // FIFO within each stripe.
        let mut last: Vec<Option<usize>> = vec![None; 4];
        for a in &arr {
            if let Some(prev) = last[a.stripe] {
                assert!(a.index > prev, "stripe {} reordered internally", a.stripe);
            }
            last[a.stripe] = Some(a.index);
        }
        // Cross-stripe jitter must actually produce a global reorder —
        // otherwise the reordering regression tests are vacuous.
        let order: Vec<usize> = arr.iter().map(|a| a.index).collect();
        assert_ne!(order, (0..64).collect::<Vec<_>>(), "expected cross-stripe reordering");
    }

    #[test]
    fn striping_shortens_the_makespan() {
        let link = Link::from_profile(&regions::AUSTRALIA);
        let s = sizes(200, 1 << 20);
        let single = striped_makespan(&link, &s, 1, &mut Rng::new(1));
        let multi = striped_makespan(&link, &s, 8, &mut Rng::new(1));
        assert!(multi < single * 0.5, "8 stripes {multi:.2}s vs 1 stripe {single:.2}s");
    }

    #[test]
    fn arrivals_are_time_ordered_and_deterministic() {
        let link = Link::from_profile(&regions::JAPAN);
        let s = sizes(40, 1 << 19);
        let a = deliver_striped(&link, &s, 3, &mut Rng::new(9));
        let b = deliver_striped(&link, &s, 3, &mut Rng::new(9));
        assert_eq!(a, b, "same seed, same arrival order");
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn empty_stream_delivers_nothing() {
        let link = Link::from_profile(&regions::CANADA);
        assert!(deliver_striped(&link, &[], 4, &mut Rng::new(0)).is_empty());
        assert_eq!(striped_makespan(&link, &[], 4, &mut Rng::new(0)), 0.0);
    }
}
