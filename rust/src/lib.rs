//! # SparrowRL
//!
//! Reproduction of *"RL over Commodity Networks: Overcoming the Bandwidth
//! Barrier with Lossless Sparse Deltas"* (CS.DC 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: lossless
//!   sparse delta checkpoints, streaming multi-stream transfer with relay
//!   fanout, heterogeneity-aware scheduling, lease-based fault tolerance,
//!   plus the substrates they need (WAN simulator, metrics, cost model,
//!   synthetic workloads) and a PJRT runtime that executes the AOT-lowered
//!   JAX/Pallas model on the request path without Python.
//! * **L2** — `python/compile/model.py`: transformer policy fwd + RL train
//!   step, lowered once to `artifacts/*.hlo.txt`.
//! * **L1** — `python/compile/kernels/`: Pallas attention and delta-diff
//!   kernels called from L2 (interpret mode on CPU).
//!
//! The public entry point is the [`session`] module: a validated
//! [`session::RunSpec`] builder plus a live [`session::Session`] handle
//! with typed event streaming. The [`daemon`] module (`sparrowrl serve`)
//! hosts many such sessions behind an HTTP/JSON control plane with
//! cross-session actor-pool arbitration.
//!
//! See DESIGN.md for the system inventory and the paper-experiment index,
//! and docs/ARCHITECTURE.md for the subsystem map (delta pipeline →
//! runtime → transport/netsim), the wire formats, the mailbox protocol,
//! the multi-region distribution-tree design, and the Session API (§2c).

pub mod actor;
pub mod bench;
pub mod config;
pub mod cost;
pub mod daemon;
pub mod data;
pub mod delta;
pub mod exp;
pub mod ledger;
pub mod metrics;
pub mod netsim;
pub mod rt;
pub mod runtime;
pub mod scheduler;
pub mod session;
pub mod sim;
pub mod trainer;
pub mod transport;
pub mod util;
