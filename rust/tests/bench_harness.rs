//! Scenario-matrix harness acceptance suite (ISSUE 8).
//!
//! Covers, in rough order of cost:
//! 1. The `smoke` suite's static coverage floor (>= 8 cells, >= 2
//!    transports, >= 2 region counts, >= 1 fault script).
//! 2. Typed scenario validation, in the `SpecError`-matrix style of
//!    `tests/session_api.rs`.
//! 3. Golden `compare` cases from synthetic OLD/NEW result literals.
//! 4. Deterministic replay: the whole smoke suite run twice agrees on
//!    every gated (non-timing) field and every checksum witness.
//! 5. The end-to-end acceptance criterion: results file round trip,
//!    self-compare passes, an injected 20% payload regression and a
//!    flipped witness both fail the gate.

use sparrowrl::bench::scenario::{FaultAxis, ScenarioBlock, SparsityAxis, TransportAxis};
use sparrowrl::bench::{
    builtin_suite, compare, run_suite, Better, ResultSet, ScenarioError, Suite,
    DEFAULT_THRESHOLD_PCT,
};
use std::collections::BTreeSet;

// ---------------------------------------------------------------- 1. coverage

#[test]
fn smoke_suite_meets_the_coverage_floor() {
    let cells = builtin_suite("smoke").unwrap().expand().unwrap();
    assert!(cells.len() >= 8, "smoke must cover >= 8 cells, has {}", cells.len());
    let transports: BTreeSet<_> = cells.iter().map(|c| c.transport).collect();
    let regions: BTreeSet<_> = cells.iter().map(|c| c.regions).collect();
    let faults: BTreeSet<_> = cells.iter().map(|c| c.fault).collect();
    assert!(transports.len() >= 2, "smoke spans {} transport(s)", transports.len());
    assert!(regions.len() >= 2, "smoke spans {} region count(s)", regions.len());
    assert!(faults.iter().any(|f| *f != FaultAxis::None), "smoke has no fault cell");
    let keys: BTreeSet<_> = cells.iter().map(|c| c.key()).collect();
    assert_eq!(keys.len(), cells.len(), "scenario keys must be unique");
}

#[test]
fn full_suite_expands_and_is_a_superset_in_spirit() {
    let smoke = builtin_suite("smoke").unwrap().expand().unwrap();
    let full = builtin_suite("full").unwrap().expand().unwrap();
    assert!(full.len() > smoke.len());
    let regions: BTreeSet<_> = full.iter().map(|c| c.regions).collect();
    assert_eq!(regions, BTreeSet::from([1, 2, 3, 4]));
    assert!(full.iter().any(|c| c.fault == FaultAxis::Preempt));
    assert!(full.iter().any(|c| c.model == "syn-m"));
}

// ---------------------------------------------- 2. typed scenario validation

fn one_block(blocks: Vec<ScenarioBlock>) -> Suite {
    Suite { name: "case".into(), blocks }
}

#[test]
fn illegal_matrices_are_rejected_with_typed_errors() {
    let d = ScenarioBlock::default;
    // (block, predicate over the expected typed error)
    let cases: Vec<(Vec<ScenarioBlock>, Box<dyn Fn(&ScenarioError) -> bool>)> = vec![
        (
            vec![ScenarioBlock { models: vec!["gpt-17t".into()], ..d() }],
            Box::new(|e| matches!(e, ScenarioError::UnknownModel(m) if m == "gpt-17t")),
        ),
        (
            vec![ScenarioBlock { regions: vec![5], ..d() }],
            Box::new(|e| matches!(e, ScenarioError::RegionsOutOfRange { regions: 5 })),
        ),
        (
            vec![ScenarioBlock { regions: vec![0], ..d() }],
            Box::new(|e| matches!(e, ScenarioError::RegionsOutOfRange { regions: 0 })),
        ),
        (
            vec![ScenarioBlock { steps: 0, ..d() }],
            Box::new(|e| matches!(e, ScenarioError::ZeroSteps)),
        ),
        (
            // Sim × elastic: the sim fleet is fixed at topology-build time.
            vec![ScenarioBlock {
                transports: vec![TransportAxis::Sim],
                faults: vec![FaultAxis::Join],
                ..d()
            }],
            Box::new(|e| matches!(e, ScenarioError::SimConflictsWithElastic { .. })),
        ),
        (
            // Crash without a real socket to kill.
            vec![ScenarioBlock { faults: vec![FaultAxis::Crash], ..d() }],
            Box::new(
                |e| matches!(e, ScenarioError::FaultNeedsTcp { fault: FaultAxis::Crash, .. }),
            ),
        ),
        (
            vec![ScenarioBlock {
                regions: vec![2],
                transports: vec![TransportAxis::Tcp],
                ..d()
            }],
            Box::new(|e| matches!(e, ScenarioError::WanConflictsWithTcp { .. })),
        ),
        (
            vec![ScenarioBlock { regions: vec![2], faults: vec![FaultAxis::Join], ..d() }],
            Box::new(|e| matches!(e, ScenarioError::WanConflictsWithFault { .. })),
        ),
        (
            // Fault pins land at steps-2, so 2 steps cannot host one.
            vec![ScenarioBlock { faults: vec![FaultAxis::Drain], steps: 2, ..d() }],
            Box::new(|e| matches!(e, ScenarioError::TooFewStepsForFault { steps: 2, .. })),
        ),
        (vec![], Box::new(|e| matches!(e, ScenarioError::EmptyMatrix))),
        (
            // Two identical blocks collide on every key.
            vec![d(), d()],
            Box::new(|e| matches!(e, ScenarioError::DuplicateKey(_))),
        ),
    ];
    for (i, (blocks, want)) in cases.into_iter().enumerate() {
        match one_block(blocks).expand() {
            Err(got) => assert!(want(&got), "case {i}: wrong error {got:?}"),
            Ok(cells) => panic!("case {i}: expanded to {} cell(s) instead of failing", cells.len()),
        }
    }
}

#[test]
fn suite_files_reject_unknown_axis_values_and_bad_json() {
    assert!(matches!(Suite::from_json("{"), Err(ScenarioError::Parse(_))));
    assert!(matches!(
        Suite::from_json(r#"{"blocks":[]}"#),
        Err(ScenarioError::Parse(_)) // missing "suite"
    ));
    let bad_transport =
        r#"{"suite":"x","blocks":[{"transports":["carrier-pigeon"]}]}"#;
    assert!(matches!(
        Suite::from_json(bad_transport),
        Err(ScenarioError::UnknownTransport(t)) if t == "carrier-pigeon"
    ));
    let bad_fault = r#"{"suite":"x","blocks":[{"faults":["meteor"]}]}"#;
    assert!(matches!(
        Suite::from_json(bad_fault),
        Err(ScenarioError::UnknownFault(f)) if f == "meteor"
    ));
    let bad_sparsity = r#"{"suite":"x","blocks":[{"sparsities":["soggy"]}]}"#;
    assert!(matches!(
        Suite::from_json(bad_sparsity),
        Err(ScenarioError::UnknownSparsity(s)) if s == "soggy"
    ));
}

// ------------------------------------------------- 3. golden compare cases

/// Two-cell baseline: one gated Lower metric + witness per cell, plus a
/// gated Higher metric and an Exact counter on the first.
fn golden_old() -> ResultSet {
    ResultSet::parse(
        r#"{"schema":1,"suite":"golden","placeholder":false,"records":[
            {"key":"a/r1/inproc/none/default/seed0","axes":{"transport":"inproc"},
             "metrics":{"payload_bytes":{"v":1000,"better":"lower","gated":true},
                        "gen_tokens":{"v":480,"better":"exact","gated":true},
                        "tokens_per_s":{"v":200,"better":"higher","gated":true},
                        "makespan_s":{"v":1.5,"better":"lower","gated":false}},
             "witness":"aaaa"},
            {"key":"b/r2/sim/none/default/seed0","axes":{"transport":"sim"},
             "metrics":{"payload_bytes":{"v":2000,"better":"lower","gated":true}},
             "witness":"bbbb"}
        ]}"#,
    )
    .unwrap()
}

fn with_payload(set: &ResultSet, key_prefix: &str, value: f64) -> ResultSet {
    let mut out = set.clone();
    for rec in &mut out.records {
        if rec.key.starts_with(key_prefix) {
            rec.metrics.get_mut("payload_bytes").unwrap().value = value;
        }
    }
    out
}

#[test]
fn golden_regression_beyond_threshold_fails_with_the_metric_named() {
    let old = golden_old();
    let new = with_payload(&old, "a/", 1300.0); // +30% > 5%
    let rep = compare(&old, &new, DEFAULT_THRESHOLD_PCT);
    assert!(!rep.passed());
    let rendered = rep.render();
    assert!(rendered.contains("REGRESSED"), "{rendered}");
    assert!(rendered.contains("payload_bytes"), "{rendered}");
    assert!(rendered.contains("FAIL"), "{rendered}");
}

#[test]
fn golden_improvement_and_within_noise_both_pass() {
    let old = golden_old();
    let improved = with_payload(&old, "a/", 600.0); // -40%
    let rep = compare(&old, &improved, DEFAULT_THRESHOLD_PCT);
    assert!(rep.passed(), "{}", rep.render());
    assert!(rep.render().contains("improved"), "{}", rep.render());
    let noise = with_payload(&old, "a/", 1030.0); // +3% < 5%
    let rep = compare(&old, &noise, DEFAULT_THRESHOLD_PCT);
    assert!(rep.passed());
    assert!(!rep.render().contains("REGRESSED"));
}

#[test]
fn golden_higher_is_better_metric_regresses_downward() {
    let old = golden_old();
    let mut new = old.clone();
    new.records[0].metrics.get_mut("tokens_per_s").unwrap().value = 100.0; // -50%
    assert!(!compare(&old, &new, DEFAULT_THRESHOLD_PCT).passed());
    new.records[0].metrics.get_mut("tokens_per_s").unwrap().value = 400.0; // +100%
    assert!(compare(&old, &new, DEFAULT_THRESHOLD_PCT).passed());
}

#[test]
fn golden_exact_metric_fails_on_any_drift_and_gauges_never_gate() {
    let old = golden_old();
    let mut new = old.clone();
    new.records[0].metrics.get_mut("gen_tokens").unwrap().value = 481.0;
    let rep = compare(&old, &new, 1000.0); // threshold is irrelevant for Exact
    assert!(!rep.passed());
    assert!(rep.render().contains("gen_tokens"));
    // An ungated gauge may move arbitrarily.
    let mut new = old.clone();
    new.records[0].metrics.get_mut("makespan_s").unwrap().value = 9000.0;
    assert!(compare(&old, &new, DEFAULT_THRESHOLD_PCT).passed());
}

#[test]
fn golden_removed_key_fails_and_added_key_passes() {
    let old = golden_old();
    let mut removed = old.clone();
    removed.records.pop();
    let rep = compare(&old, &removed, DEFAULT_THRESHOLD_PCT);
    assert!(!rep.passed());
    assert!(rep.render().contains("MISSING"), "{}", rep.render());
    let mut added = old.clone();
    added.push(
        sparrowrl::bench::ResultRecord::new("c/r1/tcp/none/default/seed0").gate(
            "payload_bytes",
            10.0,
            Better::Lower,
        ),
    );
    let rep = compare(&old, &added, DEFAULT_THRESHOLD_PCT);
    assert!(rep.passed(), "{}", rep.render());
    assert!(rep.render().contains("added"), "{}", rep.render());
}

#[test]
fn golden_witness_mismatch_fails_regardless_of_threshold() {
    let old = golden_old();
    let mut new = old.clone();
    new.records[1].witness = Some("flip".into());
    let rep = compare(&old, &new, 1e9);
    assert!(!rep.passed());
    assert!(rep.render().contains("witness"), "{}", rep.render());
}

#[test]
fn golden_suite_mismatch_fails_unless_placeholder() {
    let old = golden_old();
    let mut new = old.clone();
    new.suite = "other".into();
    assert!(!compare(&old, &new, DEFAULT_THRESHOLD_PCT).passed());
    let mut placeholder = ResultSet::new("smoke");
    placeholder.placeholder = true;
    let rep = compare(&placeholder, &golden_old(), DEFAULT_THRESHOLD_PCT);
    assert!(rep.passed(), "placeholder baseline must pass: {}", rep.render());
    assert!(rep.render().contains("placeholder"));
}

// ------------------------------------- 4 + 5. replay determinism + the gate

/// One smoke-suite execution. Expensive (runs every cell through the
/// Session API), so the replay and acceptance assertions share it.
fn run_smoke() -> ResultSet {
    let cells = builtin_suite("smoke").unwrap().expand().unwrap();
    run_suite("smoke", &cells).expect("smoke suite runs clean")
}

#[test]
fn smoke_replay_is_deterministic_and_the_gate_accepts_itself() {
    let first = run_smoke();
    let second = run_smoke();

    // -- satellite 1: replay agrees on every non-timing field ------------
    assert_eq!(first.records.len(), second.records.len());
    for (a, b) in first.records.iter().zip(&second.records) {
        assert_eq!(a.key, b.key);
        assert_eq!(a.witness, b.witness, "{}: checksum witness must replay", a.key);
        assert!(a.witness.is_some(), "{}: deterministic cell must emit a witness", a.key);
        for (name, ma) in a.metrics.iter().filter(|(_, m)| m.gated) {
            let mb = &b.metrics[name];
            assert_eq!(
                ma.value.to_bits(),
                mb.value.to_bits(),
                "{}: gated metric {name} drifted across replays ({} vs {})",
                a.key,
                ma.value,
                mb.value
            );
        }
    }
    // Replay-vs-replay through the real gate: timings differ, gate passes.
    let rep = compare(&first, &second, DEFAULT_THRESHOLD_PCT);
    assert!(rep.passed(), "{}", rep.render());

    // -- acceptance: emitted file covers the floor and round-trips -------
    let dir = std::env::temp_dir().join(format!("sprw-bench-harness-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_smoke.json");
    first.write(&path).unwrap();
    let loaded = ResultSet::load(&path).unwrap();
    assert_eq!(loaded, first, "result file must round-trip bit-exactly");
    std::fs::remove_dir_all(&dir).ok();
    assert!(loaded.records.len() >= 8);
    let transports: BTreeSet<_> =
        loaded.records.iter().filter_map(|r| r.axes.get("transport").cloned()).collect();
    let regions: BTreeSet<_> =
        loaded.records.iter().filter_map(|r| r.axes.get("regions").cloned()).collect();
    assert!(transports.len() >= 2 && regions.len() >= 2);
    assert!(loaded.records.iter().any(|r| r.axes.get("fault").map_or(false, |f| f != "none")));

    // Self-compare exits clean (exit code 0 in the CLI).
    assert!(compare(&loaded, &loaded, DEFAULT_THRESHOLD_PCT).passed());

    // Injected 20% payload regression on one cell -> nonzero exit.
    let mut worse = loaded.clone();
    let m = worse.records[0].metrics.get_mut("payload_bytes").unwrap();
    m.value *= 1.2;
    let rep = compare(&loaded, &worse, DEFAULT_THRESHOLD_PCT);
    assert!(!rep.passed(), "a 20% payload regression must fail the gate");
    assert!(rep.render().contains("payload_bytes"));

    // Flipped checksum witness -> nonzero exit.
    let mut flipped = loaded.clone();
    let w = flipped.records[1].witness.as_mut().unwrap();
    let flipped_char = if w.starts_with('0') { "1" } else { "0" };
    w.replace_range(0..1, flipped_char);
    assert!(
        !compare(&loaded, &flipped, DEFAULT_THRESHOLD_PCT).passed(),
        "a flipped determinism witness must fail the gate"
    );
}

#[test]
fn sparsity_axis_orders_payload_bytes() {
    // dense (div 16) must ship more bytes than sparse (div 1024) on the
    // same cell — the knob the scenario axis turns is real.
    use sparrowrl::bench::run_scenario;
    use sparrowrl::bench::Scenario;
    let cell = |sparsity| Scenario {
        model: "syn-xs".into(),
        regions: 1,
        transport: TransportAxis::InProc,
        fault: FaultAxis::None,
        sparsity,
        seed: 0,
        steps: 3,
    };
    let dense = run_scenario(&cell(SparsityAxis::Dense)).unwrap();
    let sparse = run_scenario(&cell(SparsityAxis::Sparse)).unwrap();
    assert!(
        dense.metrics["payload_bytes"].value > sparse.metrics["payload_bytes"].value,
        "dense regime must ship more payload ({} vs {})",
        dense.metrics["payload_bytes"].value,
        sparse.metrics["payload_bytes"].value
    );
}
