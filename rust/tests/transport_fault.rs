//! Lease-driven failover over real sockets (§5.4, executed rather than
//! simulated): kill a Tcp actor mid-step — by crash (sockets reset) or
//! by partition (sockets up, silent) — and the run must complete on the
//! survivors with the dead actor's leased prompts re-issued exactly
//! once, no global restart. Killing during the *final* step additionally
//! pins the strongest property: because a re-issued job carries the
//! original assignment's RNG seed and prompt order, the regenerated
//! rollouts are bit-identical and the final committed policy equals the
//! no-failure deterministic baseline's checksum.

use sparrowrl::delta::ModelLayout;
use sparrowrl::ledger::LeasePolicy;
use sparrowrl::rt::{RunReport, SyntheticCompute};
use sparrowrl::session::{Backend, RunSpec, Session};
use sparrowrl::transport::{KillMode, KillSpec, TcpConfig};

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-fault", 256, 64, 2, 128)
}

/// Deterministic generation + wall-clock leases: rollouts stay
/// bit-reproducible while stalls genuinely time out.
fn config(n_actors: usize, steps: u64, seed: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(n_actors)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256)
        .seed(seed)
        .deterministic()
        .wall_leases()
        .pipelined()
}

fn run(spec: &RunSpec) -> RunReport {
    let plan = spec.clone().build().expect("valid spec");
    let transport = plan.config().transport.name();
    Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session")
        .join()
        .unwrap_or_else(|e| panic!("run over {transport} failed: {e:#}"))
}

fn tcp_with_kill(kill: Option<KillSpec>) -> Backend {
    Backend::Tcp(TcpConfig { streams: 2, bits_per_s: None, kills: kill.into_iter().collect() })
}

/// Jobs for step `s` are leased against version `max(s-1, 0)` (the
/// one-step-off schedule), and version `v >= 1` is dispatched only at
/// step `v + 1` — so killing at `steps - 2` hits exactly the final step.
fn final_step_version(steps: u64) -> u64 {
    steps - 2
}

fn assert_steps_match(tag: &str, a: &RunReport, b: &RunReport) {
    assert_eq!(a.final_version, b.final_version, "{tag}: final version");
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.rho, y.rho, "{tag}: step {} rho", x.step);
        assert_eq!(x.payload_bytes, y.payload_bytes, "{tag}: step {} payload", x.step);
        assert_eq!(x.gen_tokens, y.gen_tokens, "{tag}: step {} gen tokens", x.step);
        assert_eq!(x.mean_reward, y.mean_reward, "{tag}: step {} reward", x.step);
        assert_eq!(
            x.policy_checksum, y.policy_checksum,
            "{tag}: step {} policy diverged from the no-failure baseline",
            x.step
        );
    }
}

#[test]
fn crashed_actor_final_step_recovers_bitwise_to_baseline() {
    let steps = 4;
    let base = config(3, steps, 7);
    let baseline = run(&base); // no-failure InProc reference
    assert_eq!(baseline.failovers, 0);

    let kcfg = base.clone().transport(tcp_with_kill(Some(KillSpec {
        actor: 2,
        at_version: final_step_version(steps),
        mode: KillMode::Crash,
    })));
    let failed = run(&kcfg);

    assert_eq!(failed.final_version, steps, "run completed through the failure");
    assert_eq!(failed.failovers, 1, "exactly one actor lost");
    assert!(failed.requeued_prompts > 0, "orphaned prompts migrated");
    // Exactly-once re-issue, bit-exact regeneration: every step's batch
    // accounting and committed policy equals the healthy baseline — a
    // duplicated or dropped prompt would shift gen_tokens/reward, and a
    // different RNG lane would shift the checksum.
    assert_steps_match("crash@final", &baseline, &failed);
}

#[test]
fn partitioned_actor_leases_expire_and_work_migrates_bitwise() {
    // The silent-failure case: the actor's sockets stay open but it stops
    // replying — only the wall-clock lease can detect it. Short leases
    // keep the test fast (expiry ~0.6 s).
    let steps = 3;
    let base = config(3, steps, 5);
    let baseline = run(&base); // default (long) leases: immune to CI hiccups

    // Short leases only where the stall must be detected; lease policy
    // never reaches the rollout bits, so results stay comparable.
    let kcfg = base
        .clone()
        .lease(LeasePolicy { multiplier: 2.0, min_s: 0.4, max_s: 5.0, ..Default::default() })
        .transport(tcp_with_kill(Some(KillSpec {
            actor: 1,
            at_version: final_step_version(steps),
            mode: KillMode::Stall,
        })));
    let failed = run(&kcfg);

    assert_eq!(failed.final_version, steps);
    assert_eq!(failed.failovers, 1, "stall detected via lease expiry alone");
    assert!(failed.requeued_prompts > 0);
    assert_steps_match("stall@final", &baseline, &failed);
}

#[test]
fn mid_run_crash_completes_on_survivors_with_full_batches() {
    // Killing before the last step changes later allocations (two
    // survivors split the work the baseline gave three actors), so the
    // policies legitimately diverge from a no-failure run — but every
    // step must still train on a full batch, and the failover must be
    // exactly-once.
    let steps = 5;
    let cfg = config(3, steps, 13).transport(tcp_with_kill(Some(KillSpec {
        actor: 0,
        at_version: 1, // dispatched at step 2: mid-run
        mode: KillMode::Crash,
    })));
    let report = run(&cfg);

    assert_eq!(report.final_version, steps);
    assert_eq!(report.failovers, 1);
    assert!(report.requeued_prompts > 0);
    // SyntheticCompute emits exactly max_new_tokens per completion, so a
    // full batch is a constant token count: prompts(8) * group(2) * 5.
    for s in &report.steps {
        assert_eq!(
            s.gen_tokens, 80,
            "step {}: batch incomplete after failover (lost or duplicated prompts)",
            s.step
        );
        assert!(s.payload_bytes > 0, "step {}: no delta committed", s.step);
    }
}

#[test]
fn healthy_tcp_run_with_wall_leases_never_fails_over() {
    // Wall-clock leases on a healthy fleet must be invisible: no expiry,
    // no requeue, and results identical to the virtual-clock run.
    // Pure manual-clock reference, InProc (no .wall_leases()):
    let base = RunSpec::synthetic()
        .actors(2)
        .steps(3)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256)
        .seed(9)
        .deterministic()
        .pipelined();
    let virtual_clock = run(&base);
    let wall = base.clone().wall_leases().transport(tcp_with_kill(None));
    let tcp = run(&wall);
    assert_eq!(tcp.failovers, 0);
    assert_eq!(tcp.requeued_prompts, 0);
    assert_steps_match("virtual vs wall-lease tcp", &virtual_clock, &tcp);
}
