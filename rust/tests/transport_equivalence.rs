//! Cross-backend equivalence: the transport layer must be a pure
//! *routing* change. With `LocalRunConfig::deterministic`, the same seed
//! must produce bit-identical committed policies (SHA-256
//! `policy_checksum` witness), identical per-step rho / payload bytes /
//! rewards / losses, and the same final version across:
//!
//! * the sequential reference executor (no transport at all),
//! * InProc  — in-process mailboxes (the default),
//! * Sim     — netsim WAN model: striped, jitter-reordered delta arrival,
//! * Tcp     — real loopback sockets, multi-stream segment push.
//!
//! This is the acceptance criterion for the transport API redesign: one
//! executor, three backends, zero behavioral drift — now driven through
//! the Session API (`RunSpec` backends + `Session` event-assembled
//! reports).

use sparrowrl::config::regions;
use sparrowrl::delta::ModelLayout;
use sparrowrl::netsim::Link;
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{Backend, RunSpec, Session};
use sparrowrl::transport::{SimNetConfig, TcpConfig};

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-tr-eq", 256, 64, 2, 128)
}

fn config(n_actors: usize, steps: u64, seed: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(n_actors)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2) // large enough that every step flips bf16 bits
        .segment_bytes(256) // many segments per delta: real wire traffic
        .seed(seed)
        .deterministic()
}

fn run(spec: &RunSpec, comp: &SyntheticCompute, mode: ExecMode) -> RunReport {
    let plan = spec.clone().mode(mode).build().expect("valid spec");
    let transport = plan.config().transport.name();
    Session::start_with_compute(&plan, layout(), comp.clone())
        .expect("start session")
        .join()
        .unwrap_or_else(|e| panic!("{} run over {transport} failed: {e:#}", mode.name()))
}

fn assert_equivalent(tag: &str, a: &RunReport, b: &RunReport) {
    assert_eq!(a.final_version, b.final_version, "{tag}: final version");
    assert_eq!(a.sft_losses, b.sft_losses, "{tag}: sft warmup");
    assert_eq!(a.steps.len(), b.steps.len(), "{tag}: step count");
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.step, y.step);
        assert_eq!(x.rho, y.rho, "{tag}: step {} rho", x.step);
        assert_eq!(x.payload_bytes, y.payload_bytes, "{tag}: step {} payload", x.step);
        assert_eq!(x.gen_tokens, y.gen_tokens, "{tag}: step {} gen tokens", x.step);
        assert_eq!(x.mean_reward, y.mean_reward, "{tag}: step {} reward", x.step);
        assert_eq!(x.loss, y.loss, "{tag}: step {} loss", x.step);
        assert_eq!(
            x.policy_checksum, y.policy_checksum,
            "{tag}: step {} committed policies must be bit-identical",
            x.step
        );
    }
    assert_eq!(a.failovers, 0, "{tag}: healthy runs fail nothing over");
    assert_eq!(b.failovers, 0, "{tag}: healthy runs fail nothing over");
}

fn sim_two_region(n_actors: usize, seed: u64) -> SimNetConfig {
    // Split the fleet over two jittery WAN legs so cross-stripe arrival
    // reordering is real (CANADA jitter 0.18, JAPAN similar).
    let region_of: Vec<usize> = (0..n_actors).map(|i| usize::from(i >= n_actors / 2)).collect();
    SimNetConfig {
        region_of,
        links: vec![Link::from_profile(&regions::CANADA), Link::from_profile(&regions::JAPAN)],
        streams: vec![4, 3],
        seed,
    }
}

#[test]
fn all_backends_commit_bitwise_identical_policies() {
    let comp = SyntheticCompute::new(16, 8, 64);
    let base = config(3, 4, 11);

    let seq = run(&base, &comp, ExecMode::Sequential);
    assert_eq!(seq.final_version, 4);
    assert!(seq.steps.iter().all(|s| s.rho > 0.0 && s.payload_bytes > 0));

    let inproc = run(&base, &comp, ExecMode::Pipelined);

    let simc = base.clone().transport(Backend::SimNet(sim_two_region(3, 99)));
    let sim = run(&simc, &comp, ExecMode::Pipelined);

    let tcpc = base
        .clone()
        .transport(Backend::Tcp(TcpConfig { streams: 2, bits_per_s: None, kills: vec![] }));
    let tcp = run(&tcpc, &comp, ExecMode::Pipelined);

    assert_equivalent("seq vs inproc", &seq, &inproc);
    assert_equivalent("inproc vs sim", &inproc, &sim);
    assert_equivalent("inproc vs tcp", &inproc, &tcp);
}

#[test]
fn sim_backend_matches_inproc_relay_tree_routing() {
    // The netsim-modeled relay tree (Sim) and the in-process relay
    // forwarding (InProc + DistributionSpec) are two routes for the same
    // payload: committed policies must agree with each other and with
    // flat streaming.
    let comp = SyntheticCompute::new(16, 8, 64);
    let base = config(4, 3, 21);

    let flat = run(&base, &comp, ExecMode::Pipelined);

    let tree = base
        .clone()
        .distribution(sparrowrl::rt::DistributionSpec { region_of: vec![0, 0, 1, 1] });
    let inproc_tree = run(&tree, &comp, ExecMode::Pipelined);

    let simc = base.clone().transport(Backend::SimNet(sim_two_region(4, 5)));
    let sim_tree = run(&simc, &comp, ExecMode::Pipelined);

    assert_equivalent("flat vs inproc-tree", &flat, &inproc_tree);
    assert_equivalent("flat vs sim-tree", &flat, &sim_tree);
}

#[test]
fn tcp_backend_is_self_reproducible_across_socket_interleavings() {
    // Socket scheduling must not leak into results: two Tcp runs of the
    // same seed are bit-identical (the stronger determinism contract).
    let comp = SyntheticCompute::new(16, 8, 64);
    let cfg = config(2, 3, 3)
        .transport(Backend::Tcp(TcpConfig { streams: 3, bits_per_s: None, kills: vec![] }));
    let a = run(&cfg, &comp, ExecMode::Pipelined);
    let b = run(&cfg, &comp, ExecMode::Pipelined);
    assert_equivalent("tcp vs tcp", &a, &b);
}

#[test]
fn throttled_tcp_still_matches_and_completes() {
    // WAN-emulating write throttles change timing, never results. The
    // per-step payloads here are a few KB, so 200 Mbit/s costs ~ms.
    let comp = SyntheticCompute::new(16, 8, 64);
    let base = config(2, 3, 17);
    let inproc = run(&base, &comp, ExecMode::Pipelined);
    let tcpc = base
        .clone()
        .transport(Backend::Tcp(TcpConfig { streams: 2, bits_per_s: Some(200e6), kills: vec![] }));
    let tcp = run(&tcpc, &comp, ExecMode::Pipelined);
    assert_equivalent("inproc vs throttled tcp", &inproc, &tcp);
}

#[test]
fn different_seeds_diverge_on_every_backend() {
    // Guards against the equivalence suite passing vacuously (e.g. a
    // constant checksum).
    let comp = SyntheticCompute::new(16, 8, 64);
    for (kind_a, kind_b) in [
        (Backend::InProc, Backend::InProc),
        (
            Backend::Tcp(TcpConfig::default()),
            Backend::Tcp(TcpConfig::default()),
        ),
    ] {
        let a_cfg = config(2, 3, 1).transport(kind_a);
        let b_cfg = config(2, 3, 2).transport(kind_b);
        let a = run(&a_cfg, &comp, ExecMode::Pipelined);
        let b = run(&b_cfg, &comp, ExecMode::Pipelined);
        assert_ne!(
            a.steps.last().unwrap().policy_checksum,
            b.steps.last().unwrap().policy_checksum,
            "distinct seeds must produce distinct policies"
        );
    }
}
