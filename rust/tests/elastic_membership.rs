//! Elastic membership chaos suite (ISSUE 6): live joins with
//! delta-chain / snapshot bootstrap, graceful scripted leaves,
//! spot-preemption faults with and without a usable warning window, and
//! fleet re-growth after a crash — all seeded and deterministic. The
//! load-bearing property throughout: membership changes pinned to the
//! final version boundary never perturb allocations, so the final
//! committed policy is **bitwise identical** to the no-fault baseline,
//! and every actor lost the hard way takes the PR-4 reissue path
//! (exactly-once accounting, full batches).

use sparrowrl::delta::ModelLayout;
use sparrowrl::rt::{BootstrapKind, FailReason, RunReport, SyntheticCompute};
use sparrowrl::session::{Backend, Event, RunSpec, Session, SpecError};
use sparrowrl::transport::{KillMode, KillSpec, TcpConfig};

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-elastic", 256, 64, 2, 128)
}

/// Deterministic generation + wall-clock leases (stalls and preemptions
/// genuinely time out while rollouts stay bit-reproducible).
fn config(n_actors: usize, steps: u64, seed: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(n_actors)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256)
        .seed(seed)
        .deterministic()
        .wall_leases()
        .pipelined()
}

fn run(spec: &RunSpec) -> RunReport {
    run_with_events(spec).1
}

fn run_with_events(spec: &RunSpec) -> (Vec<Event>, RunReport) {
    let plan = spec.clone().build().expect("valid spec");
    let transport = plan.config().transport.name();
    let mut session =
        Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
            .expect("start session");
    let mut events = Vec::new();
    while let Some(ev) = session.recv() {
        events.push(ev);
    }
    let report =
        session.join().unwrap_or_else(|e| panic!("run over {transport} failed: {e:#}"));
    (events, report)
}

fn tcp_with_kills(kills: Vec<KillSpec>) -> Backend {
    Backend::Tcp(TcpConfig { streams: 2, bits_per_s: None, kills })
}

/// Jobs for step `s` are leased against version `max(s-1, 0)`, so a kill
/// triggered at `steps - 2` hits exactly the final step's job.
fn final_step_version(steps: u64) -> u64 {
    steps - 2
}

/// Membership changes pinned at `steps - 1` fire after the final
/// `plan_step` (the commit boundary the last batch trains into), so they
/// can never change an allocation — the strongest determinism pin.
fn final_boundary(steps: u64) -> u64 {
    steps - 1
}

fn assert_steps_match(tag: &str, a: &RunReport, b: &RunReport) {
    assert_eq!(a.final_version, b.final_version, "{tag}: final version");
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.rho, y.rho, "{tag}: step {} rho", x.step);
        assert_eq!(x.payload_bytes, y.payload_bytes, "{tag}: step {} payload", x.step);
        assert_eq!(x.gen_tokens, y.gen_tokens, "{tag}: step {} gen tokens", x.step);
        assert_eq!(x.mean_reward, y.mean_reward, "{tag}: step {} reward", x.step);
        assert_eq!(
            x.policy_checksum, y.policy_checksum,
            "{tag}: step {} policy diverged from the no-fault baseline",
            x.step
        );
    }
}

/// The single `Joined` event of a run with one scripted join.
fn joined_of(events: &[Event]) -> (u32, u64, BootstrapKind, u64) {
    let mut found = None;
    for ev in events {
        if let Event::Joined { actor, version, bootstrap, bytes } = ev {
            assert!(found.is_none(), "more than one Joined event");
            found = Some((*actor, *version, *bootstrap, *bytes));
        }
    }
    found.expect("no Joined event")
}

#[test]
fn join_at_final_boundary_is_bitwise_for_both_bootstrap_kinds() {
    let steps = 4;
    let base = config(3, steps, 7);
    let baseline = run(&base);
    assert_eq!(baseline.failovers, 0);
    assert_eq!(baseline.joins, 0);

    let v = final_boundary(steps);
    let (chain_ev, chain) =
        run_with_events(&base.clone().join_at(3, v, BootstrapKind::DeltaChain));
    let (snap_ev, snap) = run_with_events(&base.clone().join_at(3, v, BootstrapKind::Snapshot));

    for (tag, report) in [("chain", &chain), ("snapshot", &snap)] {
        assert_eq!(report.joins, 1, "{tag}: one admitted joiner");
        assert_eq!(report.failovers, 0, "{tag}: a join is not a failure");
        assert_eq!(report.drains, 0, "{tag}");
        assert_eq!(report.requeued_prompts, 0, "{tag}: nothing migrated");
    }
    // Verified bit-exactness: the joiner echoed the SHA-256 policy
    // witness before admission, and the admission changed no allocation,
    // so both elastic runs equal the fixed-fleet baseline — and hence
    // the delta-chain joiner equals the snapshot joiner.
    assert_steps_match("join:chain@final", &baseline, &chain);
    assert_steps_match("join:snapshot@final", &baseline, &snap);

    let (actor, version, kind, chain_bytes) = joined_of(&chain_ev);
    assert_eq!((actor, version, kind), (3, v, BootstrapKind::DeltaChain));
    let (_, _, _, snap_bytes) = joined_of(&snap_ev);
    assert!(chain_bytes > 0 && snap_bytes > 0, "bootstrap bytes are accounted");
}

#[test]
fn join_over_tcp_matches_the_inproc_baseline() {
    let steps = 4;
    let base = config(3, steps, 11);
    let baseline = run(&base); // fixed-fleet InProc reference
    let tcp = run(&base
        .clone()
        .join_at(3, final_boundary(steps), BootstrapKind::DeltaChain)
        .transport(tcp_with_kills(vec![])));
    assert_eq!(tcp.joins, 1);
    assert_eq!(tcp.failovers, 0);
    assert_steps_match("join over tcp", &baseline, &tcp);
}

#[test]
fn scripted_leave_drains_without_a_failover() {
    let steps = 4;
    let base = config(3, steps, 19);
    let baseline = run(&base);

    for (tag, spec) in [
        ("inproc", base.clone().leave_at(2, final_boundary(steps))),
        (
            "tcp",
            base.clone()
                .leave_at(2, final_boundary(steps))
                .transport(tcp_with_kills(vec![])),
        ),
    ] {
        let left = run(&spec);
        assert_eq!(left.drains, 1, "{tag}: one graceful drain");
        assert_eq!(left.failovers, 0, "{tag}: a drain is not a failure");
        assert_eq!(left.preempts, 0, "{tag}");
        assert_eq!(left.requeued_prompts, 0, "{tag}: leases settled before release");
        assert_steps_match(tag, &baseline, &left);
    }
}

#[test]
fn preemption_without_warning_takes_the_reissue_path_bitwise() {
    // warn_ms: 0 — the reclaim lands before the actor can act on the
    // warning, so its leased prompts take the ordinary crash-failover
    // path; the warning still types the loss as Preempted.
    let steps = 4;
    let base = config(3, steps, 23);
    let baseline = run(&base);

    let (events, failed) = run_with_events(&base.clone().transport(tcp_with_kills(vec![
        KillSpec {
            actor: 2,
            at_version: final_step_version(steps),
            mode: KillMode::Preempt { warn_ms: 0 },
        },
    ])));
    assert_eq!(failed.preempts, 1, "the warning was observed");
    assert_eq!(failed.failovers, 1, "the kill landed before the drain");
    assert_eq!(failed.drains, 0);
    assert!(failed.requeued_prompts > 0, "orphaned prompts migrated");
    assert!(
        events.iter().any(|ev| matches!(
            ev,
            Event::Failover { actor: 2, reason: FailReason::Preempted, .. }
        )),
        "the failover is typed Preempted, not Crash"
    );
    assert_steps_match("preempt:no-warning", &baseline, &failed);
}

#[test]
fn preemption_with_a_generous_warning_drains_gracefully() {
    // A warning window longer than the remaining work: the actor
    // finishes its leases, the hub releases it, nothing is reissued.
    let steps = 4;
    let base = config(3, steps, 29);
    let baseline = run(&base);

    let warned = run(&base.clone().transport(tcp_with_kills(vec![KillSpec {
        actor: 2,
        at_version: final_step_version(steps),
        mode: KillMode::Preempt { warn_ms: 60_000 },
    }])));
    assert_eq!(warned.preempts, 1, "warning observed");
    assert_eq!(warned.drains, 1, "drained inside the window");
    assert_eq!(warned.failovers, 0, "no failover needed");
    assert_eq!(warned.requeued_prompts, 0);
    assert_steps_match("preempt:drained", &baseline, &warned);
}

#[test]
fn crash_then_join_regrows_capacity_with_full_batches() {
    // An actor crashes mid-run and a replacement joins two versions
    // later ("re-join": the fleet regains capacity under a fresh id,
    // bootstrapped over the wire). Allocations legitimately change, but
    // every step still trains on a full batch — exactly-once accounting
    // through both the loss and the growth.
    let steps = 5;
    let cfg = config(3, steps, 13)
        .join_at(3, 3, BootstrapKind::DeltaChain)
        .transport(tcp_with_kills(vec![KillSpec {
            actor: 0,
            at_version: 1, // dispatched at step 2: mid-run
            mode: KillMode::Crash,
        }]));
    let report = run(&cfg);

    assert_eq!(report.final_version, steps);
    assert_eq!(report.failovers, 1);
    assert_eq!(report.joins, 1);
    assert!(report.requeued_prompts > 0);
    // SyntheticCompute emits exactly max_new_tokens per completion, so a
    // full batch is a constant token count: prompts(8) * group(2) * 5.
    for s in &report.steps {
        assert_eq!(
            s.gen_tokens, 80,
            "step {}: batch incomplete across crash + join (lost or duplicated prompts)",
            s.step
        );
        assert!(s.payload_bytes > 0, "step {}: no delta committed", s.step);
    }
}

#[test]
fn elastic_specs_are_validated_up_front() {
    // Joiner ids must extend the day-one fleet contiguously.
    let err = config(3, 4, 0).join_at(7, 3, BootstrapKind::DeltaChain).build();
    assert!(matches!(err, Err(SpecError::ElasticJoinerIds { actors: 3, joins: 1 })));
    // Membership pins must land on a committed version.
    let err = config(3, 4, 0).leave_at(1, 9).build();
    assert!(matches!(err, Err(SpecError::ElasticVersionOutOfRange { actor: 1, version: 9, .. })));
    // The netsim fleet is fixed at topology-build time.
    let err = config(3, 4, 0)
        .join_at(3, 3, BootstrapKind::DeltaChain)
        .transport(Backend::Sim)
        .build();
    assert!(matches!(err, Err(SpecError::ElasticConflictsWithSim)));
    // sweep_ms paces the hub's poll loop; zero would spin.
    let err = config(3, 4, 0).lease_sweep_ms(0).build();
    assert!(matches!(err, Err(SpecError::ZeroSweepInterval)));
    // A custom sweep interval is accepted and survives into the plan.
    let plan = config(3, 4, 0).lease_sweep_ms(5).build().expect("legal");
    assert_eq!(plan.config().lease.sweep_ms, 5);
}
