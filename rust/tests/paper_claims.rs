//! Headline-claims gate: every quantitative claim in the paper's abstract
//! must hold in this reproduction (shape-level, per DESIGN.md §4), all
//! through the public API.

use sparrowrl::config::{self, regions, GpuClass};
use sparrowrl::cost::table6_deployments;
use sparrowrl::data::Benchmark;
use sparrowrl::metrics::geometric_mean;
use sparrowrl::sim::compute::delta_payload_bytes;
use sparrowrl::sim::driver::{run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};

fn fleet(model: &config::ModelSpec, n: usize) -> Vec<RegionSpec> {
    vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; n])]
}

fn testbed(model: &str, bench: Benchmark, sys: System) -> SimConfig {
    let model = config::model(model).unwrap();
    let n = ((model.total_params() as f64 / 1.02e9).round() as usize).clamp(4, 16);
    let f = fleet(&model, n);
    SimConfig::paper_testbed(model, bench, sys, f)
}

/// "reduces per-step transfer payload by 79x for Qwen3-8B"
#[test]
fn claim_payload_reduction_tens_of_x() {
    let m = config::model("qwen3-8b").unwrap();
    let ratio = m.dense_bytes_bf16() as f64 / delta_payload_bytes(&m, m.expected_rho) as f64;
    assert!((40.0..120.0).contains(&ratio), "payload reduction {ratio:.0}x");
}

/// "improves throughput by 2.4-9.5x over full-weight broadcast across WAN"
#[test]
fn claim_throughput_improvement_band_across_sizes_and_benchmarks() {
    let mut ratios = Vec::new();
    for bench in Benchmark::all() {
        for m in config::paper_models() {
            let sp = run(&testbed(m, bench, System::Sparrow)).throughput();
            let full = run(&testbed(m, bench, System::PrimeRlFull)).throughput();
            ratios.push(sp / full);
        }
    }
    let lo = ratios.iter().cloned().fold(f64::MAX, f64::min);
    let hi = ratios.iter().cloned().fold(f64::MIN, f64::max);
    assert!(lo >= 2.0, "min speedup {lo:.1} (paper floor 2.4)");
    assert!(hi <= 12.0, "max speedup {hi:.1} (paper ceiling 9.5)");
    assert!(hi / lo > 2.0, "spread must grow with model size");
}

/// "narrowing the throughput gap relative to an ideal RDMA single-DC
/// baseline to within 8.91%"
#[test]
fn claim_gap_to_ideal_within_paper_bound() {
    for m in config::paper_models() {
        let sp = run(&testbed(m, Benchmark::Gsm8k, System::Sparrow)).throughput();
        let ideal = run(&testbed(m, Benchmark::Gsm8k, System::IdealSingleDc)).throughput();
        let gap = 1.0 - sp / ideal;
        assert!(
            (-0.005..0.0891 + 0.02).contains(&gap),
            "{m}: gap {:.2}% exceeds the paper's 8.91% (+2pp tolerance)",
            gap * 100.0
        );
    }
}

/// "under full-weight broadcast the gap is 59.0-90.3%"
#[test]
fn claim_full_broadcast_gap_is_catastrophic() {
    for m in config::paper_models() {
        let full = run(&testbed(m, Benchmark::Gsm8k, System::PrimeRlFull)).throughput();
        let ideal = run(&testbed(m, Benchmark::Gsm8k, System::IdealSingleDc)).throughput();
        let gap = 1.0 - full / ideal;
        assert!(gap > 0.5, "{m}: full-broadcast gap only {:.1}%", gap * 100.0);
    }
}

/// "1.21-1.59x higher tokens per dollar than reserved RDMA clusters"
#[test]
fn claim_cost_efficiency_band() {
    for (m, h100s, a100s) in [("qwen3-8b", 4usize, 8usize), ("qwen3-14b", 6, 12)] {
        let model = config::model(m).unwrap();
        let (cross, single) = table6_deployments(m).unwrap();
        let mut sp = Vec::new();
        let mut dc = Vec::new();
        for bench in Benchmark::all() {
            let mut cfg = SimConfig::paper_testbed(
                model.clone(),
                bench,
                System::Sparrow,
                fleet(&model, a100s),
            );
            cfg.trainer_gpus = h100s;
            sp.push(run(&cfg).throughput());
            let mut dc_cfg = SimConfig::paper_testbed(
                model.clone(),
                bench,
                System::IdealSingleDc,
                vec![RegionSpec::new(regions::US_LOCAL, vec![GpuClass::H100; a100s / 2])],
            );
            dc_cfg.trainer_gpus = h100s;
            dc.push(run(&dc_cfg).throughput());
        }
        let norm = cross.tokens_per_dollar(geometric_mean(&sp))
            / single.tokens_per_dollar(geometric_mean(&dc));
        assert!(
            (1.05..1.85).contains(&norm),
            "{m}: tokens/$ advantage {norm:.2}x outside band (paper 1.21-1.59x)"
        );
    }
}

/// "sparse delta transfer scales better as actors span multiple DCs"
#[test]
fn claim_multi_dc_robustness() {
    let model = config::model("qwen3-4b").unwrap();
    let spread = |sys: System| {
        let mut out = Vec::new();
        for n_dc in [1usize, 4] {
            let regs = [regions::CANADA, regions::JAPAN, regions::NETHERLANDS, regions::ICELAND];
            let mut fl: Vec<RegionSpec> =
                regs[..n_dc].iter().map(|r| RegionSpec::new(*r, vec![])).collect();
            for i in 0..4 {
                fl[i % n_dc].gpus.push(GpuClass::A100);
            }
            out.push(run(&SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, sys, fl))
                .throughput());
        }
        out[1] / out[0]
    };
    let sparrow_retention = spread(System::Sparrow);
    let full_retention = spread(System::PrimeRlFull);
    assert!(sparrow_retention > 0.80, "sparrow keeps >=80% at 4 DCs: {sparrow_retention:.2}");
    assert!(full_retention < 0.40, "full must collapse: {full_retention:.2}");
}

/// Relay, multi-stream, and hetero-scheduling all help (ablation signs).
#[test]
fn claim_ablations_all_positive() {
    // Relay (Canada-Australia).
    let model = config::model("qwen3-8b").unwrap();
    let mk = |relay: bool| {
        let mut au = RegionSpec::new(regions::AUSTRALIA, vec![GpuClass::A100; 6]);
        au.use_relay = relay;
        let mut ca = RegionSpec::new(regions::CANADA, vec![GpuClass::A100; 2]);
        ca.use_relay = relay;
        let mut cfg = SimConfig::paper_testbed(
            model.clone(),
            Benchmark::Gsm8k,
            System::Sparrow,
            vec![ca, au],
        );
        cfg.batch /= 2; // online regime
        cfg
    };
    assert!(run(&mk(true)).throughput() > run(&mk(false)).throughput());

    // Multi-stream cuts transfer time.
    let mut s1 = testbed("qwen3-14b", Benchmark::Gsm8k, System::Sparrow);
    s1.streams = 1;
    let mut s4 = testbed("qwen3-14b", Benchmark::Gsm8k, System::Sparrow);
    s4.streams = 4;
    assert!(run(&s4).avg_transfer_time() < run(&s1).avg_transfer_time() * 0.85);
}
