//! Multi-region WAN distribution: end-to-end properties of the
//! distribution tree, striped delivery, and the runtime's relay routing.
//!
//! * **Reorder regression**: netsim's cross-stripe reordering (and a
//!   Commit overtaking its segments) must never poison the staging
//!   decoder — the committed policy is bit-identical to the sequential
//!   in-order baseline.
//! * **Exactly-once**: over random topologies, the relay tree delivers
//!   every segment exactly once to every actor (no duplicate or dropped
//!   forwards at relays).
//! * **Routing equivalence**: the pipelined executor with relay routing
//!   commits exactly the policies the sequential reference commits.

use sparrowrl::actor::{CommitResult, PolicyState};
use sparrowrl::config::regions;
use sparrowrl::delta::{ModelLayout, ParamSet};
use sparrowrl::netsim::{deliver_striped, Link};
use sparrowrl::rt::{
    policy_checksum, DistributionSpec, ExecMode, RunReport, SyntheticCompute,
};
use sparrowrl::session::{RunSpec, Session};
use sparrowrl::trainer::stream_checkpoint;
use sparrowrl::transport::relay::RelayNode;
use sparrowrl::transport::{
    split_into_segments, DistributionPlan, Reassembler, RegionTopo, Segment,
};
use sparrowrl::util::{prop, Bf16, Rng};

/// A real streaming-encoded delta (TOTAL_UNKNOWN on all but the final
/// frame), as the fused encoder ships it.
fn streamed_delta(seed: u64, segment_bytes: usize) -> (ModelLayout, ParamSet, ParamSet, Vec<Segment>) {
    let layout = ModelLayout::transformer("wan-t", 256, 64, 2, 128);
    let mut rng = Rng::new(seed);
    let old = ParamSet::random(&layout, 0.02, &mut rng);
    let mut new = old.clone();
    for t in &mut new.tensors {
        for _ in 0..32 {
            let i = rng.range(0, t.len());
            t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0040);
        }
    }
    let mut segs = Vec::new();
    let (_ckpt, _stats) =
        stream_checkpoint(&layout, &old, &new, 0, 1, segment_bytes, |seg| segs.push(seg));
    (layout, old, new, segs)
}

#[test]
fn striped_reorder_does_not_poison_staging() {
    // Baseline: in-order delivery, commit after staging.
    let (layout, old, new, segs) = streamed_delta(11, 128);
    assert!(segs.len() > 8, "need a multi-segment stream, got {}", segs.len());
    let mut baseline = PolicyState::new(layout.clone(), old.clone(), 0);
    for s in &segs {
        baseline.on_segment(s.clone()).unwrap();
    }
    assert_eq!(baseline.request_commit(1), CommitResult::Applied);
    let want = policy_checksum(baseline.params());

    // Striped WAN delivery: 4 jittered stripes over US-Canada reorder the
    // stream, and the Commit overtakes every segment.
    let link = Link::from_profile(&regions::CANADA);
    let sizes: Vec<u64> = segs.iter().map(|s| s.payload.len() as u64).collect();
    let arrivals = deliver_striped(&link, &sizes, 4, &mut Rng::new(5));
    let order: Vec<usize> = arrivals.iter().map(|a| a.index).collect();
    assert_ne!(
        order,
        (0..segs.len()).collect::<Vec<_>>(),
        "stripes must actually reorder or this test is vacuous"
    );

    let mut actor = PolicyState::new(layout, old, 0);
    assert_eq!(actor.request_commit(1), CommitResult::Deferred, "commit overtakes segments");
    let mut committed = None;
    for &i in &order {
        actor.on_segment(segs[i].clone()).unwrap_or_else(|e| {
            panic!("reordered segment {i} poisoned staging: {e}")
        });
        if let Some(outcome) = actor.on_safe_point() {
            committed = Some(outcome);
        }
    }
    assert_eq!(committed, Some((1, CommitResult::Applied)));
    assert_eq!(actor.active_version(), 1);
    assert_eq!(policy_checksum(actor.params()), want, "bit-identical to in-order baseline");
    assert_eq!(actor.params(), &new);
}

#[test]
fn reorder_regression_holds_across_stripe_counts_and_seeds() {
    prop::check("striped reorder commits the baseline policy", 15, |rng| {
        let (layout, old, new, segs) = streamed_delta(rng.next_u64(), 256 + rng.range(0, 512));
        let streams = rng.range(2, 9);
        let link = Link::from_profile(&regions::AUSTRALIA);
        let sizes: Vec<u64> = segs.iter().map(|s| s.payload.len() as u64).collect();
        let arrivals = deliver_striped(&link, &sizes, streams, rng);
        let mut actor = PolicyState::new(layout, old, 0);
        // Commit lands at a random point — possibly after every segment
        // (commit_at == arrivals.len() skips the mid-stream request and
        // exercises the plain commit-after-staging path instead).
        let commit_at = rng.range(0, arrivals.len() + 1);
        let mut done = false;
        for (k, a) in arrivals.iter().enumerate() {
            if k == commit_at {
                let _ = actor.request_commit(1);
            }
            actor.on_segment(segs[a.index].clone()).expect("no poison under reorder");
            if actor.on_safe_point() == Some((1, CommitResult::Applied)) {
                done = true;
            }
        }
        if !done {
            // commit_at == arrivals.len(): the commit was never requested
            // mid-stream; issue it now against the fully staged delta.
            assert_eq!(actor.request_commit(1), CommitResult::Applied);
        }
        assert_eq!(actor.params(), &new);
    });
}

#[test]
fn relay_tree_delivers_every_segment_exactly_once() {
    prop::check("relay tree exactly-once delivery", 15, |rng| {
        // Random topology: 1-4 regions, 1-5 actors each.
        let all = [
            regions::CANADA,
            regions::JAPAN,
            regions::NETHERLANDS,
            regions::ICELAND,
        ];
        let n_regions = rng.range(1, 5);
        let topo: Vec<RegionTopo> = (0..n_regions)
            .map(|i| RegionTopo::from_profile(&all[i], rng.range(1, 6)))
            .collect();
        let plan = DistributionPlan::build(&topo, 512);
        let payload: Vec<u8> = (0..rng.range(600, 4000)).map(|_| rng.next_u64() as u8).collect();
        let segs = split_into_segments(1, &payload, 512);
        let sizes: Vec<u64> = segs.iter().map(|s| s.payload.len() as u64).collect();

        for leg in &plan.legs {
            // Hub -> relay: striped WAN arrival order.
            let arrivals = deliver_striped(&leg.wan, &sizes, leg.streams, rng);
            let mut relay = RelayNode::new(1);
            let mut peers: Vec<Vec<Segment>> = vec![Vec::new(); leg.peers.len()];
            for a in &arrivals {
                relay.on_segment(segs[a.index].clone(), &mut peers).unwrap();
            }
            // The relay staged the full artifact...
            assert!(relay.is_staged(), "{}: relay incomplete", leg.region);
            assert_eq!(relay.forward_failures(), 0);
            assert_eq!(relay.into_staged_bytes().unwrap(), payload);
            // ...and forwarded each segment exactly once to every peer.
            for (pi, got) in peers.iter().enumerate() {
                assert_eq!(
                    got.len(),
                    segs.len(),
                    "{} peer {pi}: duplicate or dropped forwards",
                    leg.region
                );
                let mut r = Reassembler::new(1);
                for s in got {
                    r.accept(s.clone()).unwrap();
                }
                assert_eq!(r.duplicates(), 0);
                assert_eq!(r.assemble().unwrap(), payload);
            }
        }
    });
}

fn wan_cfg(n_actors: usize, steps: u64, seed: u64, spec: Option<DistributionSpec>) -> RunSpec {
    let mut s = RunSpec::synthetic()
        .actors(n_actors)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256) // many segments per delta: real relay traffic
        .seed(seed)
        .deterministic();
    if let Some(d) = spec {
        s = s.distribution(d);
    }
    s
}

fn run(spec: &RunSpec, comp: &SyntheticCompute, mode: ExecMode) -> RunReport {
    let plan = spec.clone().mode(mode).build().expect("valid spec");
    Session::start_with_compute(
        &plan,
        ModelLayout::transformer("syn-wan-eq", 256, 64, 2, 128),
        comp.clone(),
    )
    .expect("start session")
    .join()
    .unwrap_or_else(|e| panic!("{} run failed: {e:#}", mode.name()))
}

#[test]
fn pipelined_relay_routing_matches_sequential_baseline() {
    // Hub -> relay -> peer routing is a pure transport change: committed
    // policies must be bit-identical to the flat sequential reference.
    let comp = SyntheticCompute::new(16, 8, 64);
    let spec = DistributionSpec { region_of: vec![0, 0, 1, 1] };
    let cfg = wan_cfg(4, 3, 9, Some(spec));
    let seq = run(&cfg, &comp, ExecMode::Sequential);
    let pip = run(&cfg, &comp, ExecMode::Pipelined);
    assert_eq!(seq.final_version, pip.final_version);
    for (a, b) in seq.steps.iter().zip(&pip.steps) {
        assert_eq!(a.policy_checksum, b.policy_checksum, "step {} diverged", a.step);
        assert_eq!(a.rho, b.rho);
        assert_eq!(a.payload_bytes, b.payload_bytes);
    }
}

#[test]
fn relay_routing_handles_uneven_regions_and_single_relay() {
    // Region sizes 1/2/3 (one region is relay-only, no peers) and the
    // degenerate all-in-one-region tree (one relay forwards to everyone).
    let comp = SyntheticCompute::new(16, 8, 64);
    for region_of in [vec![0, 1, 1, 2, 2, 2], vec![0, 0, 0, 0, 0, 0]] {
        let spec = DistributionSpec { region_of: region_of.clone() };
        let cfg = wan_cfg(6, 2, 4, Some(spec));
        let flat = run(&wan_cfg(6, 2, 4, None), &comp, ExecMode::Pipelined);
        let tree = run(&cfg, &comp, ExecMode::Pipelined);
        assert_eq!(flat.final_version, tree.final_version);
        for (a, b) in flat.steps.iter().zip(&tree.steps) {
            assert_eq!(
                a.policy_checksum, b.policy_checksum,
                "step {} diverged under {region_of:?}",
                a.step
            );
        }
    }
}
