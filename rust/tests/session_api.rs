//! Session API acceptance suite:
//!
//! (a) every illegal `RunSpec` combination returns its typed `SpecError`
//!     (the validation matrix that used to live as scattered `bail!`s);
//! (b) the typed event stream and the `RunReport` agree exactly — same
//!     step logs, same checksums, same failover totals — because the
//!     report is assembled *from* the events;
//! (c) `abort()` mid-run tears the session down promptly with no wedged
//!     threads;
//! (d) the `Session` path commits bit-identical checksums to the legacy
//!     blocking API under `deterministic`.

use sparrowrl::delta::ModelLayout;
use sparrowrl::netsim::Link;
use sparrowrl::rt::{run_with_compute, DistributionSpec, ExecMode, SyntheticCompute};
use sparrowrl::session::{Backend, Event, RunSpec, Session, SessionStatus, SpecError, SpecNote};
use sparrowrl::transport::{SimNetConfig, TcpConfig};
use std::time::{Duration, Instant};

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-sess", 256, 64, 2, 128)
}

fn comp() -> SyntheticCompute {
    SyntheticCompute::new(16, 8, 64)
}

fn base_spec(steps: u64, seed: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(2)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256)
        .seed(seed)
        .deterministic()
}

fn sim_net(n_actors: usize) -> SimNetConfig {
    SimNetConfig::single_region(
        n_actors,
        Link::from_profile(&sparrowrl::config::regions::CANADA),
        4,
        0,
    )
}

// ---------------------------------------------------------------------
// (a) spec-validation matrix
// ---------------------------------------------------------------------

#[test]
fn every_illegal_spec_combination_returns_its_typed_error() {
    let flat_tcp = Backend::Tcp(TcpConfig::default());
    let cases: Vec<(RunSpec, SpecError)> = vec![
        (RunSpec::model("gpt-17t"), SpecError::UnknownModel("gpt-17t".into())),
        (RunSpec::model("qwen3-8b"), SpecError::AnalyticOnlyModel("qwen3-8b".into())),
        (RunSpec::synthetic().wan("wan-9"), SpecError::UnknownWanPreset("wan-9".into())),
        (
            RunSpec::synthetic().wan("wan-2").actors(3),
            SpecError::ActorsConflictWithWan { preset: "wan-2".into(), actors: 3 },
        ),
        (
            RunSpec::synthetic().sequential().wan("wan-2"),
            SpecError::SequentialConflict { feature: "a WAN preset" },
        ),
        (
            RunSpec::synthetic().sequential().transport(Backend::Sim),
            SpecError::SequentialConflict { feature: "the sim transport" },
        ),
        (
            RunSpec::synthetic().sequential().transport(flat_tcp.clone()),
            SpecError::SequentialConflict { feature: "the tcp transport" },
        ),
        (
            RunSpec::synthetic().pipelined().wan("wan-2").transport(flat_tcp.clone()),
            SpecError::TcpConflictsWithWan,
        ),
        (
            RunSpec::synthetic()
                .pipelined()
                .actors(2)
                .distribution(DistributionSpec { region_of: vec![0, 1] })
                .transport(flat_tcp),
            SpecError::TcpConflictsWithDistribution,
        ),
        (
            RunSpec::synthetic()
                .pipelined()
                .actors(2)
                .distribution(DistributionSpec { region_of: vec![0, 1] })
                .transport(Backend::Sim),
            SpecError::SimConflictsWithDistribution,
        ),
        (
            RunSpec::synthetic().pipelined().wan("wan-2").transport(Backend::SimNet(
                sim_net(4),
            )),
            SpecError::SimNetConflictsWithWan,
        ),
        (
            RunSpec::synthetic().pipelined().actors(3).transport(Backend::SimNet(
                sim_net(2),
            )),
            SpecError::SimTopologyMismatch { covers: 2, actors: 3 },
        ),
        (
            RunSpec::synthetic().actors(3).distribution(DistributionSpec {
                region_of: vec![0, 1],
            }),
            SpecError::DistributionMismatch { covers: 2, actors: 3 },
        ),
        (
            RunSpec::synthetic().wan("wan-2").distribution(DistributionSpec {
                region_of: vec![0, 0, 1, 1],
            }),
            SpecError::DistributionConflictsWithWan,
        ),
        (RunSpec::synthetic().actors(0), SpecError::ZeroActors),
        (RunSpec::synthetic().group_size(0), SpecError::ZeroGroupSize),
        (RunSpec::synthetic().segment_bytes(0), SpecError::ZeroSegmentBytes),
    ];
    for (spec, want) in cases {
        match spec.clone().build() {
            Err(got) => assert_eq!(got, want, "spec {spec:?}"),
            Ok(_) => panic!("expected {want:?} for {spec:?}"),
        }
    }
}

#[test]
fn legal_coercions_surface_as_typed_notes_not_prints() {
    let plan = RunSpec::synthetic().wan("wan-2").build().unwrap();
    assert_eq!(plan.mode(), ExecMode::Pipelined);
    assert_eq!(plan.config().n_actors, 4); // 2 regions x 2 actors
    assert!(plan
        .notes()
        .iter()
        .any(|n| matches!(n, SpecNote::WanSetsActorCount { actors: 4, .. })));
    assert!(plan
        .notes()
        .iter()
        .any(|n| matches!(n, SpecNote::PipelinedCoerced { cause: "a WAN preset" })));
    assert!(plan.notes().iter().any(|n| matches!(n, SpecNote::WanRelayTree { regions: 2, .. })));
    // The InProc relay tree derived from the preset: contiguous regions.
    assert_eq!(plan.config().distribution.as_ref().unwrap().region_of, vec![0, 0, 1, 1]);
    // Notes have human-readable Display forms.
    for n in plan.notes() {
        assert!(!format!("{n}").is_empty());
    }

    // An explicitly pipelined tcp spec needs no coercion note.
    let plan = RunSpec::synthetic()
        .pipelined()
        .transport(Backend::Tcp(TcpConfig::default()))
        .build()
        .unwrap();
    assert!(plan.notes().is_empty());

    // A plain sequential spec coerces nothing and defaults sanely.
    let plan = RunSpec::synthetic().build().unwrap();
    assert!(plan.notes().is_empty());
    assert_eq!(plan.mode(), ExecMode::Sequential);
    assert_eq!(plan.config().n_actors, 2);
}

// ---------------------------------------------------------------------
// (b) event stream vs report consistency
// ---------------------------------------------------------------------

#[test]
fn event_stream_and_report_agree_exactly() {
    let plan = base_spec(4, 11).pipelined().build().unwrap();
    let mut session = Session::start_with_compute(&plan, layout(), comp()).unwrap();
    let mut sft = 0usize;
    let mut steps = Vec::new();
    let mut committed = Vec::new();
    let mut streamed = Vec::new();
    let mut failovers = 0u64;
    let report = loop {
        match session.recv() {
            Some(Event::SftStep { loss, .. }) => {
                assert!(loss.is_finite());
                sft += 1;
            }
            Some(Event::StepCompleted(log)) => steps.push(log),
            Some(Event::Committed { version, checksum }) => committed.push((version, checksum)),
            Some(Event::DeltaStreamed { version, payload_bytes, stripes }) => {
                streamed.push((version, payload_bytes, stripes))
            }
            Some(Event::Failover { .. }) => failovers += 1,
            Some(Event::Finished(r)) => break r,
            None => panic!("stream ended without Finished"),
        }
    };
    // Same warmup, same steps, same checksums — the report IS the events.
    assert_eq!(sft, report.sft_losses.len());
    assert_eq!(steps.len(), report.steps.len());
    assert_eq!(report.steps.len(), 4);
    for (ev, rep) in steps.iter().zip(&report.steps) {
        assert_eq!(ev.step, rep.step);
        assert_eq!(ev.policy_checksum, rep.policy_checksum);
        assert_eq!(ev.rho, rep.rho);
        assert_eq!(ev.payload_bytes, rep.payload_bytes);
        assert_eq!(ev.gen_tokens, rep.gen_tokens);
    }
    // One trainer commit per version, checksums matching the step logs.
    assert_eq!(committed.len() as u64, report.final_version);
    for (i, (version, checksum)) in committed.iter().enumerate() {
        assert_eq!(*version, i as u64 + 1);
        assert_eq!(*checksum, report.steps[i].policy_checksum);
    }
    // One delta stream per version with real payload and segmentation.
    assert_eq!(streamed.len() as u64, report.final_version);
    for ((version, payload, stripes), log) in streamed.iter().zip(&report.steps) {
        assert_eq!(*version, log.step + 1);
        assert_eq!(*payload, log.payload_bytes);
        assert!(*stripes > 1, "segment_bytes=256 must cut multiple segments");
    }
    // Failover totals line up (healthy run: zero).
    assert_eq!(failovers, report.failovers);
    assert_eq!(report.failovers, 0);
    // checksum_hex is the canonical hex of the witness.
    let last = report.steps.last().unwrap();
    assert_eq!(last.checksum_hex(), sparrowrl::util::hex(&last.policy_checksum));
    assert_eq!(last.checksum_hex().len(), 64);
}

#[test]
fn try_iter_drains_the_stream_without_blocking() {
    let plan = base_spec(2, 1).build().unwrap();
    let mut session = Session::start_with_compute(&plan, layout(), comp()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut finished = false;
    let mut step_events = 0;
    while !finished {
        assert!(Instant::now() < deadline, "run never finished");
        for ev in session.try_iter().collect::<Vec<_>>() {
            match ev {
                Event::StepCompleted(_) => step_events += 1,
                Event::Finished(_) => finished = true,
                _ => {}
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(step_events, 2);
    // After Finished, the stream is exhausted.
    assert!(session.recv().is_none());
    assert!(session.join().is_ok());
}

#[test]
fn failover_events_match_report_totals() {
    use sparrowrl::transport::{KillMode, KillSpec};
    let steps = 4u64;
    let plan = RunSpec::synthetic()
        .actors(3)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256)
        .seed(7)
        .deterministic()
        .wall_leases()
        .transport(Backend::Tcp(TcpConfig {
            streams: 2,
            bits_per_s: None,
            kills: vec![KillSpec { actor: 2, at_version: steps - 2, mode: KillMode::Crash }],
        }))
        .build()
        .unwrap();
    let mut session = Session::start_with_compute(&plan, layout(), comp()).unwrap();
    let mut ev_failovers = 0u64;
    let mut ev_requeued = 0u64;
    let report = loop {
        match session.recv() {
            Some(Event::Failover { requeued, .. }) => {
                ev_failovers += 1;
                ev_requeued += requeued;
            }
            Some(Event::Finished(r)) => break r,
            Some(_) => {}
            None => panic!("stream ended without Finished"),
        }
    };
    assert_eq!(report.failovers, 1);
    assert_eq!(ev_failovers, report.failovers);
    assert_eq!(ev_requeued, report.requeued_prompts);
    assert!(ev_requeued > 0);
    assert_eq!(report.final_version, steps);
}

// ---------------------------------------------------------------------
// (c) abort
// ---------------------------------------------------------------------

#[test]
fn abort_mid_run_leaves_no_wedged_threads() {
    // Slow-ish compute + many steps: the run is mid-flight when aborted.
    let plan = base_spec(200, 3).pipelined().build().unwrap();
    let slow = comp().with_delays(Duration::from_millis(5), Duration::from_millis(5));
    let mut session = Session::start_with_compute(&plan, layout(), slow).unwrap();
    // Observe at least one live event so the abort is genuinely mid-run.
    assert!(session.recv().is_some(), "no events before abort");
    session.abort();
    let t0 = Instant::now();
    let err = session.join().expect_err("aborted run must not produce a report");
    assert!(
        format!("{err:#}").contains("abort"),
        "join error should name the abort: {err:#}"
    );
    // join() returning proves the hub thread exited; the scoped actor
    // workers cannot outlive it by construction. Promptness is the
    // no-wedged-threads witness.
    assert!(t0.elapsed() < Duration::from_secs(60), "join did not return promptly");
}

#[test]
fn dropping_an_unjoined_session_aborts_and_reaps_the_run() {
    let plan = base_spec(200, 5).pipelined().build().unwrap();
    let slow = comp().with_delays(Duration::from_millis(5), Duration::from_millis(5));
    let t0 = Instant::now();
    {
        let mut session = Session::start_with_compute(&plan, layout(), slow).unwrap();
        assert!(session.recv().is_some());
        // Drop without join(): Drop must cancel and reap the thread.
    }
    assert!(t0.elapsed() < Duration::from_secs(60), "drop did not reap the session");
}

// ---------------------------------------------------------------------
// (c') non-blocking status probes
// ---------------------------------------------------------------------

#[test]
fn status_probe_tracks_progress_and_terminal_states_without_consuming_events() {
    // Success path: status() moves Running{..} -> Finished while the
    // event stream is untouched (the probe must not consume it).
    let plan = base_spec(3, 9).pipelined().build().unwrap();
    let session = Session::start_with_compute(&plan, layout(), comp()).unwrap();
    let probe = session.probe();
    assert!(matches!(session.status(), SessionStatus::Running { .. } | SessionStatus::Finished));
    let t0 = Instant::now();
    while !probe.is_finished() {
        assert!(t0.elapsed() < Duration::from_secs(60), "run never reached terminal status");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(session.status(), SessionStatus::Finished);
    assert_eq!(probe.status().name(), "finished");
    // The stream was not consumed by polling: the full report (with all
    // 3 steps) still comes out of join().
    let report = session.join().unwrap();
    assert_eq!(report.steps.len(), 3);

    // Abort path: a probe-issued abort lands as SessionStatus::Aborted.
    let plan = base_spec(500, 9).pipelined().build().unwrap();
    let slow = comp().with_delays(Duration::from_millis(5), Duration::from_millis(5));
    let mut session = Session::start_with_compute(&plan, layout(), slow).unwrap();
    assert!(session.recv().is_some());
    let probe = session.probe();
    assert!(!probe.is_finished());
    probe.abort();
    let t0 = Instant::now();
    while !session.is_finished() {
        assert!(t0.elapsed() < Duration::from_secs(60), "abort never landed in status()");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(session.status(), SessionStatus::Aborted);
    assert!(session.status().is_terminal());
    session.join().expect_err("aborted run has no report");
}

// ---------------------------------------------------------------------
// (d) Session vs legacy blocking API, bitwise
// ---------------------------------------------------------------------

#[test]
fn session_matches_legacy_blocking_api_bitwise() {
    for mode in [ExecMode::Sequential, ExecMode::Pipelined] {
        let plan = base_spec(3, 7).mode(mode).build().unwrap();
        let legacy = run_with_compute(plan.config(), &layout(), &comp(), mode).unwrap();
        let via_session =
            Session::start_with_compute(&plan, layout(), comp()).unwrap().join().unwrap();
        assert_eq!(legacy.final_version, via_session.final_version, "{mode:?}");
        assert_eq!(legacy.sft_losses, via_session.sft_losses, "{mode:?}");
        assert_eq!(legacy.steps.len(), via_session.steps.len(), "{mode:?}");
        for (a, b) in legacy.steps.iter().zip(&via_session.steps) {
            assert_eq!(
                a.policy_checksum, b.policy_checksum,
                "{mode:?} step {}: session and legacy shim must be bit-identical",
                a.step
            );
            assert_eq!(a.rho, b.rho);
            assert_eq!(a.payload_bytes, b.payload_bytes);
            assert_eq!(a.gen_tokens, b.gen_tokens);
            assert_eq!(a.mean_reward, b.mean_reward);
            assert_eq!(a.loss, b.loss);
        }
    }
}

#[test]
fn synthetic_plan_refuses_artifact_start() {
    let plan = base_spec(1, 0).build().unwrap();
    let err = Session::start(&plan).expect_err("synthetic plans have no artifacts");
    assert!(format!("{err:#}").contains("start_with_compute"), "{err:#}");
}
