//! Durable runs: kill-and-resume chaos suite + chain-compaction
//! properties. A run with `persist_dir` journals every commit boundary;
//! killing it at any scripted point — including between sealing a
//! version's objects and journaling the commit — and resuming must
//! produce a committed-checksum trace bitwise identical to the
//! uninterrupted run, and `DurableStore::reconstruct` must reproduce
//! every journaled witness, with or without chain compaction. Runs on
//! the synthetic compute backend; all state lives under per-test temp
//! directories.

use sparrowrl::delta::{
    apply_delta, merge_chain, policy_witness, ApplyMode, DurableStore, JournalRecord, MergeError,
    ModelLayout, ParamSet, RecoveryError, SparseDelta, TensorDelta,
};
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{Event, RunSpec, Session, SpecError};
use sparrowrl::util::{prop, Bf16, Rng};
use std::fs;
use std::path::{Path, PathBuf};

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-dur", 256, 64, 2, 128)
}

/// Unique per test (and per process) so parallel test binaries never
/// collide; removed up front so reruns start clean.
fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sprw-persist-{}-{name}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn spec(steps: u64, seed: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(2)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2) // large enough that every step flips bf16 bits
        .segment_bytes(256)
        .seed(seed)
        .deterministic()
}

fn run(spec: RunSpec, mode: ExecMode) -> RunReport {
    let plan = spec.mode(mode).build().expect("valid spec");
    Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session")
        .join()
        .unwrap_or_else(|e| panic!("run failed: {e:#}"))
}

/// Run a spec that must fail; returns the rendered error chain.
fn run_err(spec: RunSpec, mode: ExecMode) -> String {
    let plan = spec.mode(mode).build().expect("valid spec");
    match Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64)) {
        Ok(s) => match s.join() {
            Ok(r) => panic!("run unexpectedly succeeded at v{}", r.final_version),
            Err(e) => format!("{e:#}"),
        },
        Err(e) => format!("{e:#}"),
    }
}

/// Every step the resumed run produced must be bitwise identical to the
/// same step of the uninterrupted baseline (checksum AND the scalar
/// stats feeding it), and the two runs must end at the same version.
fn assert_tail_matches(baseline: &RunReport, resumed: &RunReport, resume_version: u64) {
    assert_eq!(baseline.final_version, resumed.final_version, "final version");
    assert_eq!(
        resumed.steps.first().map(|s| s.step),
        Some(resume_version),
        "resumed run must pick up at the regenerated in-flight batch"
    );
    assert_eq!(
        resumed.steps.len() as u64,
        baseline.final_version - resume_version,
        "resumed run replays exactly the lost steps"
    );
    for r in &resumed.steps {
        let b = &baseline.steps[r.step as usize];
        assert_eq!(b.step, r.step);
        assert_eq!(b.loss, r.loss, "step {} loss", r.step);
        assert_eq!(b.mean_reward, r.mean_reward, "step {} reward", r.step);
        assert_eq!(b.rho, r.rho, "step {} rho", r.step);
        assert_eq!(b.payload_bytes, r.payload_bytes, "step {} payload", r.step);
        assert_eq!(b.gen_tokens, r.gen_tokens, "step {} gen tokens", r.step);
        assert_eq!(
            b.policy_checksum, r.policy_checksum,
            "step {}: resumed commit must be bit-identical to the uninterrupted run",
            r.step
        );
    }
}

/// The journaled witness of `version`, straight from the records.
fn journaled_witness(store: &DurableStore, version: u64) -> [u8; 32] {
    match &store.records()[version as usize] {
        JournalRecord::Genesis { witness, .. } => *witness,
        JournalRecord::Commit { witness, .. } => *witness,
    }
}

fn copy_dir(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for e in fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let to = dst.join(e.file_name());
        if e.file_type().unwrap().is_dir() {
            copy_dir(&e.path(), &to);
        } else {
            fs::copy(e.path(), &to).unwrap();
        }
    }
}

/// Rewind the journal to its first `keep` records — the on-disk state
/// of a run killed right after journaling record `keep - 1`. Objects
/// and manifests of later versions are left behind on purpose: that is
/// exactly the kill point between object-seal and journal-append.
fn truncate_journal(dir: &Path, keep: usize) {
    let path = dir.join("journal.jsonl");
    let raw = fs::read_to_string(&path).unwrap();
    let kept: Vec<&str> = raw.lines().take(keep).collect();
    fs::write(&path, format!("{}\n", kept.join("\n"))).unwrap();
}

// ---------------------------------------------------------------------
// Kill-and-resume chaos suite
// ---------------------------------------------------------------------

#[test]
fn kill_after_any_commit_resumes_bitwise_identical() {
    let base_dir = test_dir("chaos-base");
    let baseline = run(spec(6, 7).persist_dir(&base_dir), ExecMode::Sequential);
    assert_eq!(baseline.final_version, 6);
    for kill_v in [1u64, 3, 5] {
        let dir = test_dir(&format!("chaos-kill{kill_v}"));
        copy_dir(&base_dir, &dir);
        // Kill point: the journal holds genesis + commits 1..=kill_v;
        // later versions' objects and manifests are already sealed on
        // disk (the seal-vs-journal window) but must stay invisible.
        truncate_journal(&dir, kill_v as usize + 1);
        let store = DurableStore::open(&dir).unwrap_or_else(|e| panic!("recover: {e}"));
        assert_eq!(store.last_version(), Some(kill_v), "sealed-but-unjournaled is invisible");
        drop(store);
        let resumed = run(spec(6, 7).persist_dir(&dir).resume(), ExecMode::Sequential);
        assert_tail_matches(&baseline, &resumed, kill_v);
        // The healed store must be byte-identical to the uninterrupted
        // run's: same journal, same manifests (recommits are idempotent
        // and the replay is bit-exact).
        assert_eq!(
            fs::read(base_dir.join("journal.jsonl")).unwrap(),
            fs::read(dir.join("journal.jsonl")).unwrap(),
            "kill at v{kill_v}: healed journal diverged"
        );
        for v in 0..=6u64 {
            assert_eq!(
                fs::read(base_dir.join("refs").join(format!("v{v}"))).unwrap(),
                fs::read(dir.join("refs").join(format!("v{v}"))).unwrap(),
                "kill at v{kill_v}: manifest v{v} diverged"
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }
    let _ = fs::remove_dir_all(&base_dir);
}

#[test]
fn kill_before_any_seal_resumes_bitwise_identical() {
    // Kill point: right after journaling commit 3, before any of v4's
    // objects hit disk (manifests of later versions removed too — the
    // "clean crash between iterations" state).
    let base_dir = test_dir("cleankill-base");
    let baseline = run(spec(5, 11).persist_dir(&base_dir), ExecMode::Sequential);
    let dir = test_dir("cleankill");
    copy_dir(&base_dir, &dir);
    truncate_journal(&dir, 4);
    for v in 4..=5u64 {
        fs::remove_file(dir.join("refs").join(format!("v{v}"))).unwrap();
    }
    let resumed = run(spec(5, 11).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert_tail_matches(&baseline, &resumed, 3);
    assert_eq!(
        fs::read(base_dir.join("journal.jsonl")).unwrap(),
        fs::read(dir.join("journal.jsonl")).unwrap(),
    );
    let _ = fs::remove_dir_all(&base_dir);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn pipelined_resume_matches_sequential_baseline() {
    // The overlapped executor must persist and resume the very same
    // trace the sequential reference produces.
    let baseline = run(spec(6, 13), ExecMode::Sequential);
    let dir = test_dir("pipelined");
    let partial = run(spec(3, 13).persist_dir(&dir), ExecMode::Pipelined);
    for s in &partial.steps {
        assert_eq!(
            s.policy_checksum, baseline.steps[s.step as usize].policy_checksum,
            "pre-kill step {}",
            s.step
        );
    }
    let resumed = run(spec(6, 13).persist_dir(&dir).resume(), ExecMode::Pipelined);
    assert_tail_matches(&baseline, &resumed, 3);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn extending_a_finished_run_matches_longer_baseline() {
    // Resuming a cleanly finished short run with a larger step budget
    // must continue exactly where an uninterrupted long run would be.
    let baseline = run(spec(6, 17), ExecMode::Sequential);
    let dir = test_dir("extend");
    let short = run(spec(3, 17).persist_dir(&dir), ExecMode::Sequential);
    assert_eq!(short.final_version, 3);
    let resumed = run(spec(6, 17).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert_tail_matches(&baseline, &resumed, 3);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn abort_mid_run_then_resume_completes_the_trace() {
    // A genuine (not synthesized) kill: cooperative abort somewhere
    // mid-run, then resume to the full budget.
    let baseline = run(spec(8, 23), ExecMode::Sequential);
    let dir = test_dir("abort");
    let plan = spec(8, 23).persist_dir(&dir).build().expect("valid spec");
    let mut sess = Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session");
    let mut commits = 0u64;
    while let Some(ev) = sess.recv() {
        if matches!(ev, Event::StepCompleted(_)) {
            commits += 1;
            if commits == 2 {
                sess.abort();
            }
        }
    }
    // The abort lands at a cancellation point; if it raced past the last
    // one the run simply finished — both outcomes leave a valid store.
    let _ = sess.join();
    let store = DurableStore::open(&dir).unwrap_or_else(|e| panic!("recover after abort: {e}"));
    let v = store.last_version().expect("at least the genesis is durable");
    assert!(v >= 2, "two commits were observed before the abort");
    drop(store);
    let resumed = run(spec(8, 23).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert_eq!(resumed.final_version, 8);
    if v < 8 {
        assert_tail_matches(&baseline, &resumed, v);
    }
    let store = DurableStore::open(&dir).unwrap();
    let policy = store.reconstruct(&layout(), 8).unwrap_or_else(|e| panic!("reconstruct: {e}"));
    assert_eq!(
        policy_witness(&policy),
        baseline.steps[7].policy_checksum,
        "resumed store must reconstruct the uninterrupted run's final policy"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn resume_at_the_exact_step_budget_is_a_noop() {
    let dir = test_dir("noop");
    let first = run(spec(3, 29).persist_dir(&dir), ExecMode::Sequential);
    let resumed = run(spec(3, 29).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert_eq!(resumed.final_version, first.final_version);
    assert!(resumed.steps.is_empty(), "nothing left to replay");
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Journal damage
// ---------------------------------------------------------------------

#[test]
fn torn_journal_tail_is_truncated_and_resumable() {
    let baseline = run(spec(5, 31), ExecMode::Sequential);
    let dir = test_dir("torn");
    run(spec(3, 31).persist_dir(&dir), ExecMode::Sequential);
    // A half-written record with no newline: the classic torn append.
    let journal = dir.join("journal.jsonl");
    let mut raw = fs::read(&journal).unwrap();
    raw.extend_from_slice(br#"{"kind":"commit","version":4,"wit"#);
    fs::write(&journal, &raw).unwrap();
    let store = DurableStore::open(&dir).unwrap_or_else(|e| panic!("torn tail must heal: {e}"));
    assert_eq!(store.last_version(), Some(3));
    drop(store);
    let resumed = run(spec(5, 31).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert_tail_matches(&baseline, &resumed, 3);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_mid_journal_record_is_a_typed_error() {
    let dir = test_dir("midcorrupt");
    run(spec(3, 37).persist_dir(&dir), ExecMode::Sequential);
    let journal = dir.join("journal.jsonl");
    let raw = fs::read_to_string(&journal).unwrap();
    let mut lines: Vec<String> = raw.lines().map(str::to_string).collect();
    // Valid JSON, wrong schema, NOT on the final line: no torn-tail
    // excuse applies — this is real corruption and must be refused.
    lines[1] = r#"{"kind":"mystery"}"#.to_string();
    fs::write(&journal, format!("{}\n", lines.join("\n"))).unwrap();
    match DurableStore::open(&dir) {
        Err(RecoveryError::CorruptJournal { line, .. }) => assert_eq!(line, 1),
        Err(other) => panic!("expected CorruptJournal, got {other}"),
        Ok(_) => panic!("corrupt journal must not recover"),
    }
    // Through the session API the same store must refuse to resume.
    let err = run_err(spec(5, 37).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert!(err.contains("journal"), "unhelpful error: {err}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn missing_object_fails_resume_with_a_typed_error() {
    let dir = test_dir("missingobj");
    run(spec(3, 41).persist_dir(&dir), ExecMode::Sequential);
    // Remove one referenced object; recovery names it.
    let victim = fs::read_dir(dir.join("objects")).unwrap().next().unwrap().unwrap().path();
    fs::remove_file(&victim).unwrap();
    match DurableStore::open(&dir) {
        Err(RecoveryError::MissingObject { .. }) => {}
        Err(other) => panic!("expected MissingObject, got {other}"),
        Ok(_) => panic!("missing object must not recover"),
    }
    let err = run_err(spec(5, 41).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert!(err.contains("missing object"), "unhelpful error: {err}");
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Spec / config guards
// ---------------------------------------------------------------------

#[test]
fn resume_spec_guards_reject_unsound_combinations() {
    assert_eq!(
        spec(3, 1).resume().build().unwrap_err(),
        SpecError::ResumeNeedsPersistDir
    );
    let nondet = RunSpec::synthetic()
        .actors(2)
        .steps(3)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .seed(1)
        .persist_dir("/tmp/never-used")
        .resume();
    assert_eq!(nondet.build().unwrap_err(), SpecError::ResumeRequiresDeterministic);
}

#[test]
fn resume_refuses_an_empty_store_and_fresh_runs_refuse_a_full_one() {
    let dir = test_dir("guards");
    let err = run_err(spec(3, 43).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert!(err.contains("nothing to resume"), "unhelpful error: {err}");
    let _ = fs::remove_dir_all(&dir);
    run(spec(2, 43).persist_dir(&dir), ExecMode::Sequential);
    let err = run_err(spec(2, 43).persist_dir(&dir), ExecMode::Sequential);
    assert!(err.contains("already holds a durable run"), "unhelpful error: {err}");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_mismatched_identity() {
    let dir = test_dir("identity");
    run(spec(3, 47).persist_dir(&dir), ExecMode::Sequential);
    // Different run seed: the journaled genesis pins it.
    let err = run_err(spec(5, 48).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert!(err.contains("run_seed"), "unhelpful error: {err}");
    // Smaller step budget than the run already reached.
    let err = run_err(spec(2, 47).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert!(err.contains("already at v3"), "unhelpful error: {err}");
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Reconstruction + compaction
// ---------------------------------------------------------------------

#[test]
fn reconstruct_matches_live_checksums_at_every_version() {
    let dir = test_dir("reconstruct");
    let report = run(spec(5, 53).persist_dir(&dir), ExecMode::Sequential);
    let store = DurableStore::open(&dir).unwrap_or_else(|e| panic!("recover: {e}"));
    let l = layout();
    for v in 1..=5u64 {
        let policy = store.reconstruct(&l, v).unwrap_or_else(|e| panic!("reconstruct v{v}: {e}"));
        let w = policy_witness(&policy);
        assert_eq!(w, journaled_witness(&store, v), "v{v} journal witness");
        assert_eq!(
            w,
            report.steps[v as usize - 1].policy_checksum,
            "v{v} live run checksum"
        );
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn compaction_is_bit_exact_and_the_store_stays_resumable() {
    let baseline = run(spec(7, 59), ExecMode::Sequential);
    let dir = test_dir("compact");
    let report = run(spec(5, 59).persist_dir(&dir), ExecMode::Sequential);
    let l = layout();
    let mut store = DurableStore::open(&dir).unwrap_or_else(|e| panic!("recover: {e}"));
    // Partial fold first: D_1..D_3 collapse to one object; versions on
    // both sides of the fold still reconstruct to their witnesses.
    let stats = store.compact(&l, Some(3)).unwrap_or_else(|e| panic!("compact(3): {e}"));
    assert_eq!(stats.upto, 3);
    assert!(stats.compacted_bytes > 0 && stats.compacted_bytes <= stats.chain_bytes);
    // Then the default full fold supersedes it.
    let stats = store.compact(&l, None).unwrap_or_else(|e| panic!("compact: {e}"));
    assert_eq!(stats.upto, 5);
    for v in 1..=5u64 {
        let policy = store.reconstruct(&l, v).unwrap_or_else(|e| panic!("reconstruct v{v}: {e}"));
        assert_eq!(
            policy_witness(&policy),
            report.steps[v as usize - 1].policy_checksum,
            "v{v} after compaction"
        );
    }
    drop(store);
    // A compacted store is still a valid resume source.
    let resumed = run(spec(7, 59).persist_dir(&dir).resume(), ExecMode::Sequential);
    assert_tail_matches(&baseline, &resumed, 5);
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// merge_chain properties
// ---------------------------------------------------------------------

/// One random Assign-mode delta v-1 -> v over `tensors` tensors of
/// `numel` elements each, at roughly `density` nonzeros per tensor.
fn random_delta(rng: &mut Rng, v: u64, tensors: u32, numel: u64, density: f64) -> SparseDelta {
    let mut td = Vec::new();
    for t in 0..tensors {
        // Not every tensor appears in every delta (real extracts skip
        // untouched tensors); empty updates are legal too.
        if rng.below(4) == 0 {
            continue;
        }
        let k = ((numel as f64 * density) as usize).min(numel as usize);
        let idx = prop::sparse_indices(rng, numel, k);
        let vals = idx.iter().map(|_| Bf16(rng.next_u64() as u16)).collect();
        td.push(TensorDelta { tensor: t, idx, vals });
    }
    SparseDelta { version: v, base_version: v - 1, model_fp: 0xD00D, mode: ApplyMode::Assign, tensors: td }
}

#[test]
fn folding_a_chain_equals_sequential_application() {
    // Densities from 0.01% to 50%, random chain lengths: the folded
    // delta applied once must be bit-identical to replaying the chain.
    let densities = [0.0001, 0.001, 0.01, 0.1, 0.5];
    prop::check("merge_chain folds bit-exactly", 40, |rng| {
        let tensors = rng.range(1, 5) as u32;
        let numel = rng.range(256, 8192) as u64;
        let len = rng.range(1, 9) as u64;
        let density = densities[rng.range(0, densities.len())];
        let chain: Vec<SparseDelta> =
            (1..=len).map(|v| random_delta(rng, v, tensors, numel, density)).collect();
        let base = ParamSet {
            tensors: (0..tensors)
                .map(|_| (0..numel).map(|_| Bf16(rng.next_u64() as u16)).collect())
                .collect(),
        };
        let mut replayed = base.clone();
        for d in &chain {
            apply_delta(&mut replayed, d);
        }
        let folded = merge_chain(&chain).expect("valid chain folds");
        assert_eq!(folded.base_version, 0);
        assert_eq!(folded.version, len);
        let mut once = base.clone();
        apply_delta(&mut once, &folded);
        assert_eq!(
            policy_witness(&once),
            policy_witness(&replayed),
            "folded apply diverged (len {len}, density {density})"
        );
    });
}

#[test]
fn merge_chain_rejects_unfoldable_chains() {
    let mut rng = Rng::new(9);
    let mut chain: Vec<SparseDelta> = (1..=3u64).map(|v| random_delta(&mut rng, v, 2, 64, 0.1)).collect();
    assert_eq!(merge_chain(&[]), Err(MergeError::Empty));
    chain[1].mode = ApplyMode::Add;
    assert_eq!(merge_chain(&chain), Err(MergeError::AddMode { version: 2 }));
    chain[1].mode = ApplyMode::Assign;
    chain[1].base_version = 7;
    assert_eq!(merge_chain(&chain), Err(MergeError::NonContiguous { expected: 1, found: 7 }));
    chain[1].base_version = 1;
    chain[2].model_fp = 0xBEEF;
    assert_eq!(merge_chain(&chain), Err(MergeError::ModelMismatch));
}
