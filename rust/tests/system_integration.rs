//! Whole-system integration: the real runtime loop (PJRT compute + delta
//! transfer + ledger + scheduler) and the TCP transport path.

use sparrowrl::actor::{CommitResult, PolicyState};
use sparrowrl::delta::{extract_delta, ApplyMode, DeltaCheckpoint, ModelLayout, ParamSet};
use sparrowrl::rt::net::{push_segments_multistream, read_msg, write_msg, Msg};
use sparrowrl::session::{RunSpec, Session};
use sparrowrl::transport::split_into_segments;
use sparrowrl::util::{Bf16, Rng};
use std::net::{TcpListener, TcpStream};

fn artifacts_present(model: &str) -> bool {
    let dir = sparrowrl::runtime::artifacts_dir();
    let ok = dir.join(format!("{model}_policy_fwd.hlo.txt")).exists();
    if !ok {
        eprintln!("SKIP: artifacts for {model} missing; run `make artifacts`");
    }
    ok
}

#[test]
fn local_rl_loop_end_to_end() {
    if !artifacts_present("sparrow-xs") {
        return;
    }
    let plan = RunSpec::model("sparrow-xs").steps(3).sft_steps(10).build().expect("valid spec");
    let report = Session::start(&plan).expect("start").join().expect("local run");
    assert_eq!(report.steps.len(), 3);
    assert_eq!(report.final_version, 3);
    // SFT losses must be finite and broadly decreasing.
    assert!(report.sft_losses.iter().all(|l| l.is_finite()));
    assert!(
        report.sft_losses.last().unwrap() < report.sft_losses.first().unwrap(),
        "sft: {:?}",
        report.sft_losses
    );
    for s in &report.steps {
        assert!(s.rho > 0.0 && s.rho < 0.5, "rho={}", s.rho);
        assert!(s.payload_bytes > 0 && s.payload_bytes < s.dense_bytes);
        assert!(s.gen_tokens > 0);
        assert!((0.0..=1.0).contains(&s.mean_reward));
    }
}

#[test]
fn local_rl_loop_rl_at_small_lr_is_sparse() {
    if !artifacts_present("sparrow-xs") {
        return;
    }
    let plan = RunSpec::model("sparrow-xs")
        .steps(2)
        .sft_steps(5)
        .lr_rl(1e-6)
        .build()
        .expect("valid spec");
    let report = Session::start(&plan).expect("start").join().expect("local run");
    // At post-training lr, the paper's regime: ~1% nonzero (allow slack
    // for the tiny model).
    assert!(report.mean_rho() < 0.08, "mean rho {:.4}", report.mean_rho());
}

/// Trainer-side: push a checkpoint over real TCP (4 parallel sockets),
/// actor-side: reassemble, stage, commit, acknowledge. The full §5.2
/// transfer path over actual sockets.
#[test]
fn tcp_multistream_transfer_stages_and_commits() {
    let layout = ModelLayout::transformer("t", 256, 64, 2, 128);
    let mut rng = Rng::new(9);
    let p0 = ParamSet::random(&layout, 0.02, &mut rng);
    let mut p1 = p0.clone();
    for t in &mut p1.tensors {
        for _ in 0..20 {
            let i = rng.range(0, t.len());
            t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0101);
        }
    }
    let ckpt = DeltaCheckpoint::seal(&extract_delta(&layout, &p0, &p1, 0, 1, ApplyMode::Assign));
    let segs = split_into_segments(1, &ckpt.bytes, 256);
    let n_streams = 4usize;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let expect_segments = segs.len();
    let ckpt_version = ckpt.version;
    let ckpt_hash = ckpt.hash;

    // Actor thread: accept N segment streams + 1 control stream; read
    // each stream to completion in its own thread (blocking I/O).
    let actor = std::thread::spawn(move || {
        let conns: Vec<TcpStream> =
            (0..n_streams + 1).map(|_| listener.accept().unwrap().0).collect();
        let mut conns = conns.into_iter();
        let seg_handles: Vec<_> = (0..n_streams)
            .map(|_| {
                let mut c = conns.next().unwrap();
                std::thread::spawn(move || {
                    let mut segs = Vec::new();
                    while let Ok(Msg::Seg(s)) = read_msg(&mut c) {
                        segs.push(s);
                    }
                    segs
                })
            })
            .collect();
        let mut ctl = conns.next().unwrap();
        let mut state = PolicyState::new(layout, p0, 0);
        let mut got = 0usize;
        for h in seg_handles {
            for seg in h.join().unwrap() {
                state.on_segment(seg).unwrap();
                got += 1;
            }
        }
        assert_eq!(got, expect_segments);
        assert!(state.is_staged(ckpt_version));
        match read_msg(&mut ctl).unwrap() {
            Msg::Commit { version } => {
                assert_eq!(version, ckpt_version);
                assert_eq!(state.commit(version), CommitResult::Applied);
                write_msg(&mut ctl, &Msg::Activated { actor: 0, version, hash: ckpt_hash })
                    .unwrap();
            }
            other => panic!("expected Commit, got {other:?}"),
        }
        state
    });

    // Trainer side: open sockets, push striped segments (throttled), then
    // close the segment sockets and commit over the control socket.
    let mut streams: Vec<TcpStream> =
        (0..n_streams).map(|_| TcpStream::connect(addr).unwrap()).collect();
    let mut ctl = TcpStream::connect(addr).unwrap();
    push_segments_multistream(&mut streams, &segs, Some(200e6)).unwrap();
    drop(streams); // EOF lets the actor's reader threads finish
    write_msg(&mut ctl, &Msg::Commit { version: ckpt_version }).unwrap();
    match read_msg(&mut ctl).unwrap() {
        Msg::Activated { version, hash, .. } => {
            assert_eq!(version, ckpt_version);
            assert_eq!(hash, ckpt_hash);
        }
        other => panic!("expected Activated, got {other:?}"),
    }
    let state = actor.join().unwrap();
    assert_eq!(state.params(), &p1, "bit-exact across real TCP");
}
