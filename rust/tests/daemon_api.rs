//! Control-plane daemon acceptance suite, over real loopback HTTP:
//!
//! (a) the daemon hosts two *concurrent* deterministic sessions and
//!     streams both to completion over SSE — and each run's final policy
//!     checksum is **bitwise identical** to the same spec run directly
//!     through the `Session` API (multiplexing changes nothing);
//! (b) `POST /runs/{id}/abort` tears a live run down promptly;
//! (c) malformed submissions come back as typed errors — 400 for shape,
//!     422 carrying the `SpecError` variant name for illegal specs;
//! (d) admission control: a third run past the session cap is queued
//!     (not rejected, not oversubscribed) and runs when a slot frees;
//! (e) hostile input: oversized bodies, unknown routes, wrong verbs.

use sparrowrl::bench::scenario::bench_model;
use sparrowrl::daemon::{
    http_get, http_post, AlertRules, Daemon, DaemonConfig, DaemonHandle, SseClient,
};
use sparrowrl::rt::SyntheticCompute;
use sparrowrl::session::{RunSpec, Session};
use sparrowrl::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

fn daemon(max_sessions: usize, actor_pool: usize) -> DaemonHandle {
    Daemon::spawn(DaemonConfig {
        addr: "127.0.0.1:0".to_string(), // ephemeral port per test
        max_sessions,
        actor_pool,
        rules: AlertRules::default(),
        ..DaemonConfig::default()
    })
    .expect("spawn daemon")
}

/// A submission body matching [`direct_checksum`]'s spec exactly.
fn spec_json(seed: u64, steps: u64) -> String {
    format!(
        "{{\"model\":\"syn-xs\",\"steps\":{steps},\"sft_steps\":1,\"actors\":2,\
         \"group_size\":2,\"max_new_tokens\":5,\"seed\":{seed}}}"
    )
}

/// The same run executed directly through the `Session` API on the same
/// synthetic compute the daemon provisions — the bitwise ground truth.
fn direct_checksum(seed: u64, steps: u64) -> String {
    let plan = RunSpec::synthetic()
        .actors(2)
        .steps(steps)
        .sft_steps(1)
        .group_size(2)
        .max_new_tokens(5)
        .seed(seed)
        .deterministic()
        .build()
        .expect("legal spec");
    let model = bench_model("syn-xs").expect("bench preset");
    let comp = SyntheticCompute::new(model.b_train, model.b_gen, model.max_seq)
        .with_delays(Duration::from_millis(4), Duration::from_millis(3));
    let report = Session::start_with_compute(&plan, model.layout.clone(), comp)
        .expect("start session")
        .join()
        .expect("run succeeds");
    report.steps.last().expect("has steps").checksum_hex()
}

fn submit(addr: SocketAddr, body: &str) -> (u16, Json) {
    let resp = http_post(addr, "/runs", body).expect("POST /runs");
    let json = Json::parse(&resp.body).unwrap_or(Json::Null);
    (resp.status, json)
}

fn run_status(addr: SocketAddr, id: &str) -> Json {
    let resp = http_get(addr, &format!("/runs/{id}")).expect("GET /runs/{id}");
    assert_eq!(resp.status, 200, "{}", resp.body);
    Json::parse(&resp.body).expect("snapshot is JSON")
}

fn wait_until<F: FnMut() -> bool>(what: &str, timeout: Duration, mut done: F) {
    let start = Instant::now();
    while !done() {
        assert!(start.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

// ---------------------------------------------------------------------
// (a) concurrent multiplexed runs == direct Session runs, bit for bit
// ---------------------------------------------------------------------

#[test]
fn two_concurrent_runs_stream_to_completion_with_direct_session_checksums() {
    let handle = daemon(4, 16);
    let addr = handle.addr();

    let (st1, body1) = submit(addr, &spec_json(11, 4));
    let (st2, body2) = submit(addr, &spec_json(22, 4));
    assert_eq!(st1, 201, "{body1:?}");
    assert_eq!(st2, 201, "{body2:?}");
    let id1 = body1.get("id").and_then(Json::as_str).expect("id").to_string();
    let id2 = body2.get("id").and_then(Json::as_str).expect("id").to_string();
    assert_ne!(id1, id2);

    // Tail both SSE streams to the end. The stream replays from seq 0
    // (both submissions already happened), so nothing is missed; the
    // server closes each stream after the terminal status frame.
    let mut checksums = Vec::new();
    for id in [&id1, &id2] {
        let mut sse = SseClient::connect(addr, &format!("/runs/{id}/events")).expect("SSE");
        let mut events = Vec::new();
        while let Some(ev) = sse.next_event().expect("SSE read") {
            events.push(ev);
        }
        // Event taxonomy: per-step `step`, per-version `delta`+`commit`,
        // lifecycle `status` frames, with monotonically increasing ids.
        for kind in ["status", "step", "delta", "commit"] {
            assert!(events.iter().any(|e| e.event == kind), "run {id}: no {kind} event");
        }
        let ids: Vec<u64> = events.iter().filter_map(|e| e.id).collect();
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "SSE seq not monotonic: {ids:?}");
        let last = events.last().expect("events");
        assert_eq!(last.event, "status");
        let data = Json::parse(&last.data).expect("status data");
        assert_eq!(data.get("status").and_then(Json::as_str), Some("finished"));
        let sum = data
            .get("final_checksum")
            .and_then(Json::as_str)
            .expect("terminal status carries the checksum")
            .to_string();
        checksums.push(sum);
    }

    // The multiplexed runs committed exactly what direct sessions do.
    assert_eq!(checksums[0], direct_checksum(11, 4));
    assert_eq!(checksums[1], direct_checksum(22, 4));
    // Different seeds diverge — no cross-session state bleed.
    assert_ne!(checksums[0], checksums[1]);

    // The snapshot agrees with the stream.
    let snap = run_status(addr, &id1);
    assert_eq!(snap.get("status").and_then(Json::as_str), Some("finished"));
    assert_eq!(
        snap.get("final_checksum").and_then(Json::as_str),
        Some(checksums[0].as_str())
    );
    let analytics = snap.get("analytics").expect("analytics block");
    assert_eq!(analytics.get("steps").and_then(Json::as_u64), Some(4));
    assert!(analytics.get("tokens_per_dollar").and_then(Json::as_f64).is_some());
    handle.shutdown();
}

// ---------------------------------------------------------------------
// (b) abort mid-run
// ---------------------------------------------------------------------

#[test]
fn abort_lands_promptly_and_is_idempotent() {
    let handle = daemon(2, 8);
    let addr = handle.addr();
    // ~7ms emulated compute per step: would run for half a minute.
    let (status, body) = submit(addr, &spec_json(7, 5000));
    assert_eq!(status, 201);
    let id = body.get("id").and_then(Json::as_str).expect("id").to_string();

    wait_until("run to start", Duration::from_secs(10), || {
        run_status(addr, &id).get("status").and_then(Json::as_str) == Some("running")
    });
    let aborted_at = Instant::now();
    let resp = http_post(addr, &format!("/runs/{id}/abort"), "").expect("abort");
    assert_eq!(resp.status, 200);
    wait_until("abort to land", Duration::from_secs(5), || {
        run_status(addr, &id).get("status").and_then(Json::as_str) == Some("aborted")
    });
    assert!(aborted_at.elapsed() < Duration::from_secs(5));
    // Idempotent: aborting a terminal run is a 200 no-op.
    let again = http_post(addr, &format!("/runs/{id}/abort"), "").expect("abort again");
    assert_eq!(again.status, 200);
    assert_eq!(
        run_status(addr, &id).get("status").and_then(Json::as_str),
        Some("aborted")
    );
    handle.shutdown();
}

// ---------------------------------------------------------------------
// (c) typed submission errors
// ---------------------------------------------------------------------

#[test]
fn illegal_specs_return_typed_422s_and_malformed_json_400s() {
    let handle = daemon(2, 8);
    let addr = handle.addr();
    let kind_of = |body: &str| {
        Json::parse(body)
            .ok()
            .and_then(|j| j.get("error")?.get("kind")?.as_str().map(str::to_string))
    };

    // Illegal spec → 422 with the typed SpecError variant name.
    let resp = http_post(addr, "/runs", "{\"actors\": 0}").expect("post");
    assert_eq!(resp.status, 422, "{}", resp.body);
    assert_eq!(kind_of(&resp.body).as_deref(), Some("ZeroActors"));

    let resp = http_post(addr, "/runs", "{\"wan\": \"wan-2\", \"actors\": 3}").expect("post");
    assert_eq!(resp.status, 422);
    assert_eq!(kind_of(&resp.body).as_deref(), Some("ActorsConflictWithWan"));

    let resp = http_post(addr, "/runs", "{\"model\": \"syn-xxl\"}").expect("post");
    assert_eq!(resp.status, 422);
    assert_eq!(kind_of(&resp.body).as_deref(), Some("UnknownModel"));

    // A run that can never fit the pool is a typed daemon-level 422.
    let resp = http_post(addr, "/runs", "{\"actors\": 9}").expect("post");
    assert_eq!(resp.status, 422);
    assert_eq!(kind_of(&resp.body).as_deref(), Some("ExceedsActorPool"));

    // Shape problems are 400s.
    for bad in ["not json", "[1,2]", "{\"stepz\": 3}", "{\"steps\": \"three\"}"] {
        let resp = http_post(addr, "/runs", bad).expect("post");
        assert_eq!(resp.status, 400, "body {bad:?} -> {}", resp.body);
        assert_eq!(kind_of(&resp.body).as_deref(), Some("Parse"), "{bad:?}");
    }
    // Nothing was admitted.
    let list = http_get(addr, "/runs").expect("list");
    assert_eq!(
        Json::parse(&list.body).unwrap().get("runs").and_then(Json::as_arr).map(<[Json]>::len),
        Some(0)
    );
    handle.shutdown();
}

// ---------------------------------------------------------------------
// (d) admission: queue past the cap, never oversubscribe
// ---------------------------------------------------------------------

#[test]
fn third_run_past_the_session_cap_queues_then_completes() {
    let handle = daemon(2, 4); // 2 session slots, pool of 4 (2 runs x 2 actors)
    let addr = handle.addr();
    let (s1, b1) = submit(addr, &spec_json(1, 40));
    let (s2, b2) = submit(addr, &spec_json(2, 40));
    let (s3, b3) = submit(addr, &spec_json(3, 4));
    assert_eq!((s1, s2, s3), (201, 201, 201));
    // The first two took both session slots (and the whole pool); the
    // third must be admitted as queued — not rejected, not started.
    assert_eq!(b3.get("status").and_then(Json::as_str), Some("queued"));
    let id3 = b3.get("id").and_then(Json::as_str).expect("id").to_string();

    // While anything is live, the shared pool is never oversubscribed.
    let all_ids: Vec<String> = [&b1, &b2, &b3]
        .iter()
        .map(|b| b.get("id").and_then(Json::as_str).unwrap().to_string())
        .collect();
    wait_until("all runs to finish", Duration::from_secs(60), || {
        let idx = http_get(addr, "/").expect("index");
        let pool = Json::parse(&idx.body).unwrap();
        let pool = pool.get("pool").expect("pool block");
        let used = pool.get("actors_in_use").and_then(Json::as_u64).unwrap();
        let running = pool.get("running").and_then(Json::as_u64).unwrap();
        assert!(used <= 4, "pool oversubscribed: {used} slots in use");
        assert!(running <= 2, "session cap breached: {running} running");
        all_ids.iter().all(|id| {
            run_status(addr, id).get("status").and_then(Json::as_str) == Some("finished")
        })
    });
    // The queued run produced the same bits it would have produced alone.
    let snap = run_status(addr, &id3);
    assert_eq!(
        snap.get("final_checksum").and_then(Json::as_str),
        Some(direct_checksum(3, 4).as_str())
    );
    handle.shutdown();
}

// ---------------------------------------------------------------------
// (e) hostile input on the wire
// ---------------------------------------------------------------------

#[test]
fn hostile_requests_get_bounded_typed_rejections() {
    let handle = daemon(2, 8);
    let addr = handle.addr();

    // Unknown route / unknown run / wrong verb.
    assert_eq!(http_get(addr, "/nope").expect("404").status, 404);
    assert_eq!(http_get(addr, "/runs/r999").expect("404").status, 404);
    assert_eq!(http_post(addr, "/runs/r999/abort", "").expect("404").status, 404);
    assert_eq!(http_post(addr, "/healthz", "").expect("405").status, 405);
    assert_eq!(http_post(addr, "/runs/r1/events", "").expect("405").status, 405);

    // A hostile Content-Length is rejected from the header alone —
    // before any body bytes exist to read, and before any allocation.
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(stream, "POST /runs HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n").expect("send");
    stream.flush().expect("flush");
    let mut resp = String::new();
    stream.read_to_string(&mut resp).expect("read 413");
    assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");

    // Garbage framing gets a 400, not a hang or a panic.
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(stream, "EXPLODE\r\n\r\n").expect("send");
    stream.flush().expect("flush");
    let mut resp = String::new();
    stream.read_to_string(&mut resp).expect("read 400");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");

    // The daemon is still healthy afterwards.
    let health = http_get(addr, "/healthz").expect("healthz");
    assert_eq!(health.status, 200);
    assert_eq!(health.body, "ok\n");
    handle.shutdown();
}
