//! Model-registry + hot-swap acceptance suite:
//!
//! (a) the swap composition `compose(invert(chain_A), chain_B)` applied
//!     to a live policy holding A@v is **bitwise identical** to a fresh
//!     reconstruction of B@w, property-tested over random chain pairs at
//!     densities from 0.01% to 50%;
//! (b) N fine-tunes published off one shared SFT base store that base
//!     exactly once (content-addressed dedup by object count);
//! (c) a live run hot-swaps an actor onto a published fine-tune through
//!     both executors, shipping fewer bytes than a dense snapshot, with
//!     the post-swap checksum verified against the published witness;
//! (d) `gc` never collects objects a pinned in-flight swap still reads,
//!     even across threads, and collects them once the pin drops;
//! (e) registry/run directory confusion and unknown names/versions are
//!     typed errors, publish is idempotent and contradictions conflict;
//! (f) the daemon serves the registry over HTTP with the 404/409/422
//!     error contract.
//!
//! Runs on the synthetic compute backend with the `syn-xs` bench layout
//! (so daemon `POST /models` can name the same preset); all state lives
//! under per-test temp directories.

use sparrowrl::bench::scenario::bench_model;
use sparrowrl::daemon::{http_get, http_post, AlertRules, Daemon, DaemonConfig, DaemonHandle};
use sparrowrl::delta::{
    apply_delta, expect_run_dir, merge_chain, policy_witness, swap_delta, ApplyMode, DurableStore,
    ModelLayout, ModelRegistry, ParamSet, RecoveryError, SparseDelta, TensorDelta,
};
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{Event, RunSpec, Session, SpecError};
use sparrowrl::util::json::Json;
use sparrowrl::util::{prop, Bf16, Rng};
use std::fs;
use std::path::PathBuf;

fn layout() -> ModelLayout {
    bench_model("syn-xs").expect("bench preset").layout
}

/// Unique per test (and per process) so parallel test binaries never
/// collide; removed up front so reruns start clean.
fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sprw-regswap-{}-{name}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// All fixture runs share one seed + SFT configuration, so their
/// post-SFT genesis policies — the registry bases — are bit-identical.
fn spec(steps: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(2)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2) // large enough that every step flips bf16 bits
        .segment_bytes(256)
        .seed(71)
        .deterministic()
}

fn run(spec: RunSpec, mode: ExecMode) -> RunReport {
    let plan = spec.mode(mode).build().expect("valid spec");
    Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session")
        .join()
        .unwrap_or_else(|e| panic!("run failed: {e:#}"))
}

/// Run a spec that must fail; returns the rendered error chain.
fn run_err(spec: RunSpec, mode: ExecMode) -> String {
    let plan = spec.mode(mode).build().expect("valid spec");
    match Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64)) {
        Ok(s) => match s.join() {
            Ok(r) => panic!("run unexpectedly succeeded at v{}", r.final_version),
            Err(e) => format!("{e:#}"),
        },
        Err(e) => format!("{e:#}"),
    }
}

/// Run a spec collecting the full event stream alongside the report.
fn run_with_events(spec: RunSpec, mode: ExecMode) -> (RunReport, Vec<Event>) {
    let plan = spec.mode(mode).build().expect("valid spec");
    let mut sess = Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session");
    let mut events = Vec::new();
    while let Some(ev) = sess.recv() {
        events.push(ev);
    }
    let report = sess.join().unwrap_or_else(|e| panic!("run failed: {e:#}"));
    (report, events)
}

struct Fixture {
    reg: PathBuf,
    dir_a: PathBuf,
    dir_b: PathBuf,
    a: RunReport,
    b: RunReport,
}

/// Train two fine-tunes off one shared SFT base and publish both:
/// `ft-a` = 3 RL steps, `ft-b` = 5 RL steps, identical seed/SFT config.
fn seed_registry(tag: &str) -> Fixture {
    let reg = test_dir(&format!("{tag}-registry"));
    let dir_a = test_dir(&format!("{tag}-run-a"));
    let dir_b = test_dir(&format!("{tag}-run-b"));
    let a = run(spec(3).persist_dir(&dir_a).publish_to(&reg, "ft-a"), ExecMode::Sequential);
    let b = run(spec(5).persist_dir(&dir_b).publish_to(&reg, "ft-b"), ExecMode::Sequential);
    Fixture { reg, dir_a, dir_b, a, b }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        for d in [&self.reg, &self.dir_a, &self.dir_b] {
            let _ = fs::remove_dir_all(d);
        }
    }
}

// ---------------------------------------------------------------------
// (a) swap composition == fresh reconstruct, over random chain pairs
// ---------------------------------------------------------------------

/// One random Assign-mode delta v-1 -> v at roughly `density` nonzeros
/// per tensor (not every tensor appears in every delta, like real
/// extracts).
fn random_delta(rng: &mut Rng, v: u64, tensors: u32, numel: u64, density: f64) -> SparseDelta {
    let mut td = Vec::new();
    for t in 0..tensors {
        if rng.below(4) == 0 {
            continue;
        }
        let k = ((numel as f64 * density) as usize).min(numel as usize);
        let idx = prop::sparse_indices(rng, numel, k);
        let vals = idx.iter().map(|_| Bf16(rng.next_u64() as u16)).collect();
        td.push(TensorDelta { tensor: t, idx, vals });
    }
    SparseDelta { version: v, base_version: v - 1, model_fp: 0xF00D, mode: ApplyMode::Assign, tensors: td }
}

#[test]
fn swap_composition_matches_fresh_reconstruct() {
    // Densities from 0.01% to 50%, random chain lengths for both
    // fine-tunes: retargeting a policy that replayed chain A via the
    // composed swap delta must reproduce the exact bits of replaying
    // chain B from the shared base.
    let densities = [0.0001, 0.001, 0.01, 0.1, 0.5];
    prop::check("registry swap composition is bit-exact", 40, |rng| {
        let tensors = rng.range(1, 5) as u32;
        let numel = rng.range(256, 8192) as u64;
        let len_a = rng.range(1, 7) as u64;
        let len_b = rng.range(1, 7) as u64;
        let da = densities[rng.range(0, densities.len())];
        let db = densities[rng.range(0, densities.len())];
        let base = ParamSet {
            tensors: (0..tensors)
                .map(|_| (0..numel).map(|_| Bf16(rng.next_u64() as u16)).collect())
                .collect(),
        };
        let chain_a: Vec<SparseDelta> =
            (1..=len_a).map(|v| random_delta(rng, v, tensors, numel, da)).collect();
        let chain_b: Vec<SparseDelta> =
            (1..=len_b).map(|v| random_delta(rng, v, tensors, numel, db)).collect();
        let fa = merge_chain(&chain_a).expect("chain A folds");
        let fb = merge_chain(&chain_b).expect("chain B folds");

        let mut fresh = base.clone();
        apply_delta(&mut fresh, &fb);
        let mut actor = base.clone();
        apply_delta(&mut actor, &fa);

        let d = swap_delta(&base, &fa, &fb).expect("swap composes");
        assert_eq!(d.base_version, len_a, "swap spans source version");
        assert_eq!(d.version, len_b, "swap spans target version");
        apply_delta(&mut actor, &d);
        assert_eq!(
            policy_witness(&actor),
            policy_witness(&fresh),
            "swap not bit-exact (len {len_a}x{len_b}, densities {da}/{db})"
        );
    });
}

// ---------------------------------------------------------------------
// (b) cross-run dedup: one base object, witnesses match the live runs
// ---------------------------------------------------------------------

#[test]
fn n_fine_tunes_share_one_base_object() {
    let fx = seed_registry("dedup");
    let reg = ModelRegistry::open(&fx.reg).expect("open registry");
    let ma = reg.model("ft-a").expect("ft-a published");
    let mb = reg.model("ft-b").expect("ft-b published");
    assert_eq!(ma.base, mb.base, "same SFT config must dedup to one shared base object");

    // The pool holds exactly base + two folded artifacts, nothing else.
    let objects: Vec<String> = fs::read_dir(fx.reg.join("objects"))
        .expect("objects dir")
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .filter(|n| !n.starts_with('.'))
        .collect();
    assert_eq!(objects.len(), 3, "base stored once across 2 fine-tunes: {objects:?}");

    // Published witnesses are the live runs' final committed checksums.
    assert_eq!(
        reg.witness("ft-a", 3).unwrap(),
        fx.a.steps.last().unwrap().policy_checksum,
        "ft-a witness"
    );
    assert_eq!(
        reg.witness("ft-b", 5).unwrap(),
        fx.b.steps.last().unwrap().policy_checksum,
        "ft-b witness"
    );
    // Reconstruction reproduces (and internally verifies) the witness.
    let policy = reg.reconstruct(&layout(), "ft-b", 5).expect("reconstruct ft-b@5");
    assert_eq!(policy_witness(&policy), reg.witness("ft-b", 5).unwrap());

    // Unknown lookups are typed, not stringly.
    assert!(matches!(reg.witness("ghost", 1), Err(RecoveryError::UnknownModel { .. })));
    assert!(matches!(reg.witness("ft-a", 99), Err(RecoveryError::UnknownModelVersion { .. })));
}

// ---------------------------------------------------------------------
// (e) publish: idempotent republish, typed conflicts
// ---------------------------------------------------------------------

#[test]
fn republish_is_idempotent_and_contradictions_conflict() {
    let fx = seed_registry("conflict");
    let mut reg = ModelRegistry::open(&fx.reg).expect("open registry");
    let store_a = DurableStore::open(&fx.dir_a).expect("recover run A");

    // Identical republish: nothing new, no error.
    let rep = reg.publish(&store_a, &layout(), "ft-a", None).expect("idempotent republish");
    assert_eq!(rep.version, 3);
    assert!(!rep.base_was_new, "base must dedup");
    assert!(!rep.object_was_new, "identical fold must dedup");

    // A determinism replica published under a new name shares both
    // objects with the original.
    let rep = reg.publish(&store_a, &layout(), "ft-a-replica", None).expect("replica publish");
    assert!(!rep.base_was_new && !rep.object_was_new, "replica stores zero new bytes");

    // Same version, different bytes: a run off the same base with a
    // different RL learning rate contradicts ft-a@3.
    let dir_c = test_dir("conflict-run-c");
    run(spec(3).lr_rl(5e-3).persist_dir(&dir_c), ExecMode::Sequential);
    let store_c = DurableStore::open(&dir_c).expect("recover run C");
    match reg.publish(&store_c, &layout(), "ft-a", None) {
        Err(RecoveryError::RegistryConflict { model, .. }) => assert_eq!(model, "ft-a"),
        Err(other) => panic!("expected RegistryConflict, got {other}"),
        Ok(r) => panic!("contradicting publish must fail, got {r:?}"),
    }
    let _ = fs::remove_dir_all(&dir_c);

    // Hostile model names never reach the filesystem.
    match reg.publish(&store_a, &layout(), "../escape", None) {
        Err(RecoveryError::RegistryConflict { .. }) => {}
        Err(other) => panic!("expected RegistryConflict, got {other}"),
        Ok(r) => panic!("path-traversal name must fail, got {r:?}"),
    }
}

// ---------------------------------------------------------------------
// (c) live hot-swap, both executors
// ---------------------------------------------------------------------

#[test]
fn hot_swap_retargets_a_live_actor_bit_exactly() {
    let fx = seed_registry("swap");
    let dense_bytes = layout().total_params() * 2;
    for mode in [ExecMode::Sequential, ExecMode::Pipelined] {
        // Same config as run A, so the run's final policy IS ft-a@3 and
        // `locate` finds the swap source; actor 0 is then retargeted to
        // ft-b@5 via the composed delta. The runtime fails the run if
        // the post-swap checksum differs from the published witness, so
        // a surfaced Swapped event implies bit-exactness.
        let (report, events) =
            run_with_events(spec(3).registry(&fx.reg).swap_to(0, "ft-b", 5), mode);
        assert_eq!(report.swaps, 1, "{mode:?}: one actor retargeted");
        let (actor, model, version, bytes) = events
            .iter()
            .find_map(|e| match e {
                Event::Swapped { actor, model, version, bytes } => {
                    Some((*actor, model.clone(), *version, *bytes))
                }
                _ => None,
            })
            .unwrap_or_else(|| panic!("{mode:?}: no Swapped event"));
        assert_eq!((actor, model.as_str(), version), (0, "ft-b", 5), "{mode:?}");
        assert!(bytes > 0, "{mode:?}: swap ships a real payload");
        assert!(
            bytes < dense_bytes,
            "{mode:?}: swap payload {bytes} must beat the dense snapshot {dense_bytes}"
        );
    }
}

#[test]
fn hot_swap_of_an_unpublished_policy_is_a_typed_failure() {
    // A valid-but-empty registry: the run's final policy matches no
    // published model, so the swap epilogue must fail actionably.
    let reg_dir = test_dir("unpub-registry");
    ModelRegistry::open(&reg_dir).expect("init registry");
    let err = run_err(spec(3).registry(&reg_dir).swap_to(0, "ft-b", 5), ExecMode::Sequential);
    assert!(err.contains("publish this configuration first"), "unhelpful error: {err}");
    let _ = fs::remove_dir_all(&reg_dir);

    // Published source, unknown target: the typed registry error
    // surfaces through the run failure.
    let fx = seed_registry("unpub-target");
    let err = run_err(spec(3).registry(&fx.reg).swap_to(0, "ghost", 1), ExecMode::Sequential);
    assert!(err.contains("ghost"), "unhelpful error: {err}");
}

#[test]
fn swap_spec_guards_reject_unsound_combinations() {
    assert_eq!(
        spec(3).swap_to(0, "m", 1).build().unwrap_err(),
        SpecError::SwapNeedsRegistry
    );
    assert_eq!(
        spec(3).registry("/tmp/never-used").swap_to(9, "m", 1).build().unwrap_err(),
        SpecError::SwapActorOutOfRange { actor: 9, n_actors: 2 }
    );
    assert_eq!(
        spec(3)
            .registry("/tmp/never-used")
            .swap_to(0, "m", 1)
            .swap_to(0, "m2", 2)
            .build()
            .unwrap_err(),
        SpecError::DuplicateSwapActor { actor: 0 }
    );
}

// ---------------------------------------------------------------------
// (d) gc vs in-flight swap pins, across threads
// ---------------------------------------------------------------------

#[test]
fn gc_never_collects_objects_a_pinned_swap_still_reads() {
    let fx = seed_registry("gc");
    let mut reg = ModelRegistry::open(&fx.reg).expect("open registry");
    let src_obj = reg.model("ft-a").unwrap().versions[0].object.clone();
    let src_path = fx.reg.join("objects").join(format!("{src_obj}.sprw"));

    // An in-flight swap pins base + both folded artifacts...
    let pin = reg.pin_swap(("ft-a", 3), ("ft-b", 5)).expect("pin swap objects");
    assert_eq!(reg.pinned().len(), 3, "base + source fold + target fold");
    let composed =
        reg.compose_swap(&layout(), ("ft-a", 3), ("ft-b", 5)).expect("compose swap");
    // ...then the source model is unpublished mid-swap, and gc runs on
    // another thread while the pin is still held on this one.
    reg.unpublish("ft-a").expect("unpublish source");
    let sweeper = std::thread::spawn(move || {
        let stats = reg.gc().expect("gc with pins held");
        (reg, stats)
    });
    let (mut reg, stats) = sweeper.join().expect("gc thread");
    assert_eq!(stats.collected, 0, "nothing may be collected mid-swap: {stats:?}");
    assert_eq!(stats.retained_pinned, 1, "the orphaned source fold survives on its pin");
    assert!(src_path.exists(), "pinned object file must survive gc");

    // The composed delta still lands bit-exactly on a policy holding
    // ft-a@3 (reconstructed from the source run's durable store).
    let store_a = DurableStore::open(&fx.dir_a).expect("recover run A");
    let mut actor = store_a.reconstruct(&layout(), 3).expect("reconstruct A@3");
    apply_delta(&mut actor, &composed);
    assert_eq!(
        policy_witness(&actor),
        reg.witness("ft-b", 5).unwrap(),
        "pinned swap composition stays bit-exact after unpublish + gc"
    );

    // Dropping the pin releases the object to the next sweep.
    drop(pin);
    let stats = reg.gc().expect("gc after pin release");
    assert_eq!(stats.collected, 1, "{stats:?}");
    assert_eq!(stats.retained_pinned, 0, "{stats:?}");
    assert!(!src_path.exists(), "unpinned orphan must be collected");
    // ft-b and the shared base remain fully servable.
    let policy = reg.reconstruct(&layout(), "ft-b", 5).expect("ft-b survives gc");
    assert_eq!(policy_witness(&policy), reg.witness("ft-b", 5).unwrap());
}

// ---------------------------------------------------------------------
// (e) directory-kind confusion is typed at the boundary
// ---------------------------------------------------------------------

#[test]
fn registry_and_run_dirs_are_mutually_typed() {
    let dir = test_dir("dirs-run");
    run(spec(2).persist_dir(&dir), ExecMode::Sequential);
    // A durable run dir is not a registry...
    match ModelRegistry::open(&dir) {
        Err(RecoveryError::NotARegistry { path }) => assert_eq!(path, dir),
        Err(other) => panic!("expected NotARegistry, got {other}"),
        Ok(_) => panic!("a run dir must not open as a registry"),
    }
    // ...but it is a run dir; a registry is the opposite.
    expect_run_dir(&dir).expect("run dir passes the run check");
    let reg_dir = test_dir("dirs-reg");
    ModelRegistry::open(&reg_dir).expect("init registry");
    assert!(matches!(expect_run_dir(&reg_dir), Err(RecoveryError::NotARun { .. })));
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&reg_dir);
}

// ---------------------------------------------------------------------
// (f) daemon HTTP surface
// ---------------------------------------------------------------------

fn daemon_with(registry: Option<PathBuf>, max_sessions: usize, actor_pool: usize) -> DaemonHandle {
    Daemon::spawn(DaemonConfig {
        addr: "127.0.0.1:0".to_string(), // ephemeral port per test
        max_sessions,
        actor_pool,
        rules: AlertRules::default(),
        registry,
        ..DaemonConfig::default()
    })
    .expect("spawn daemon")
}

fn spec_json(seed: u64, steps: u64) -> String {
    format!(
        "{{\"model\":\"syn-xs\",\"steps\":{steps},\"sft_steps\":1,\"actors\":2,\
         \"group_size\":2,\"max_new_tokens\":5,\"seed\":{seed}}}"
    )
}

#[test]
fn daemon_without_a_registry_answers_409() {
    let handle = daemon_with(None, 2, 8);
    let addr = handle.addr();
    let resp = http_get(addr, "/models").expect("GET /models");
    assert_eq!(resp.status, 409, "{}", resp.body);
    assert!(resp.body.contains("NoRegistry"), "{}", resp.body);
    let resp = http_post(addr, "/models", "{}").expect("POST /models");
    assert_eq!(resp.status, 409, "{}", resp.body);
    assert!(resp.body.contains("NoRegistry"), "{}", resp.body);
    handle.shutdown();
}

#[test]
fn daemon_serves_models_and_swaps_with_typed_errors() {
    let fx = seed_registry("daemon");
    let handle = daemon_with(Some(fx.reg.clone()), 1, 8);
    let addr = handle.addr();

    // GET /models: the published namespace.
    let resp = http_get(addr, "/models").expect("GET /models");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = Json::parse(&resp.body).expect("models JSON");
    let models = j.get("models").and_then(Json::as_arr).expect("models array");
    let names: Vec<&str> =
        models.iter().filter_map(|m| m.get("name").and_then(Json::as_str)).collect();
    assert_eq!(names, ["ft-a", "ft-b"], "{}", resp.body);

    // POST /models: publishing the same run dir under a new name dedups
    // every byte against the pool.
    let body = format!(
        "{{\"run_dir\":{:?},\"name\":\"ft-a2\",\"model\":\"syn-xs\"}}",
        fx.dir_a.display().to_string()
    );
    let resp = http_post(addr, "/models", &body).expect("POST /models");
    assert_eq!(resp.status, 201, "{}", resp.body);
    let j = Json::parse(&resp.body).expect("publish JSON");
    assert_eq!(j.get("base_was_new").and_then(Json::as_bool), Some(false), "{}", resp.body);
    assert_eq!(j.get("object_was_new").and_then(Json::as_bool), Some(false), "{}", resp.body);

    // POST /models with the registry itself as run_dir: typed 409.
    let body = format!(
        "{{\"run_dir\":{:?},\"name\":\"bad\",\"model\":\"syn-xs\"}}",
        fx.reg.display().to_string()
    );
    let resp = http_post(addr, "/models", &body).expect("POST /models");
    assert_eq!(resp.status, 409, "{}", resp.body);
    assert!(resp.body.contains("NotARun"), "{}", resp.body);

    // Occupy the single session slot, then queue a second run to amend.
    let resp = http_post(addr, "/runs", &spec_json(1, 60)).expect("submit long run");
    assert_eq!(resp.status, 201, "{}", resp.body);
    let long_id = Json::parse(&resp.body)
        .ok()
        .and_then(|j| j.get("id").and_then(Json::as_str).map(str::to_string))
        .expect("long run id");
    let resp = http_post(addr, "/runs", &spec_json(2, 2)).expect("submit queued run");
    assert_eq!(resp.status, 201, "{}", resp.body);
    let queued_id = Json::parse(&resp.body)
        .ok()
        .and_then(|j| j.get("id").and_then(Json::as_str).map(str::to_string))
        .expect("queued run id");

    let swap = |id: &str, body: &str| {
        http_post(addr, &format!("/runs/{id}/swap"), body).expect("POST swap")
    };
    // Unknown fine-tune / version: 404 regardless of run phase.
    let resp = swap(&queued_id, "{\"actor\":0,\"model\":\"ghost\",\"version\":1}");
    assert_eq!(resp.status, 404, "{}", resp.body);
    assert!(resp.body.contains("UnknownModel"), "{}", resp.body);
    let resp = swap(&queued_id, "{\"actor\":0,\"model\":\"ft-b\",\"version\":99}");
    assert_eq!(resp.status, 404, "{}", resp.body);
    assert!(resp.body.contains("UnknownModelVersion"), "{}", resp.body);
    // Legal amendment of a queued run: 200.
    let resp = swap(&queued_id, "{\"actor\":0,\"model\":\"ft-b\",\"version\":5}");
    assert_eq!(resp.status, 200, "{}", resp.body);
    // Plan rules still apply: 422 carrying the SpecError name.
    let resp = swap(&queued_id, "{\"actor\":0,\"model\":\"ft-a\",\"version\":3}");
    assert_eq!(resp.status, 422, "{}", resp.body);
    assert!(resp.body.contains("DuplicateSwapActor"), "{}", resp.body);
    let resp = swap(&queued_id, "{\"actor\":9,\"model\":\"ft-b\",\"version\":5}");
    assert_eq!(resp.status, 422, "{}", resp.body);
    assert!(resp.body.contains("SwapActorOutOfRange"), "{}", resp.body);

    // A no-longer-queued run refuses amendment: abort it, then 409.
    let resp = http_post(addr, &format!("/runs/{queued_id}/abort"), "").expect("abort queued");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let resp = swap(&queued_id, "{\"actor\":1,\"model\":\"ft-b\",\"version\":5}");
    assert_eq!(resp.status, 409, "{}", resp.body);
    assert!(resp.body.contains("NotQueued"), "{}", resp.body);

    let _ = http_post(addr, &format!("/runs/{long_id}/abort"), "");
    handle.shutdown();
}
