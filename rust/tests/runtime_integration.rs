//! Integration: the rust coordinator executing the AOT JAX/Pallas
//! artifacts through PJRT — the request path with no Python.
//!
//! Requires `make artifacts` (sparrow-xs). Tests self-skip with a loud
//! message if artifacts are absent so unit runs stay green.

use sparrowrl::actor::rollout::{generate_batch, SampleCfg};
use sparrowrl::config;
use sparrowrl::data::{pack_batch, Benchmark, Task, EOS};
use sparrowrl::delta::extract_delta;
use sparrowrl::runtime::{artifacts_dir, Engines, TrainState};
use sparrowrl::util::Rng;

fn engines(model: &str) -> Option<Engines> {
    let dir = artifacts_dir();
    if !dir.join(format!("{model}_policy_fwd.hlo.txt")).exists() {
        eprintln!("SKIP: artifacts for {model} not found in {dir:?}; run `make artifacts`");
        return None;
    }
    Some(Engines::load(&dir, model).expect("load artifacts"))
}

#[test]
fn policy_fwd_produces_finite_logits() {
    let Some(eng) = engines("sparrow-xs") else { return };
    let spec = config::model("sparrow-xs").unwrap();
    let mut rng = Rng::new(1);
    let st = TrainState::init(&spec.layout, &mut rng);
    let policy = st.to_policy();
    let (b, t, v) = (eng.manifest.b_gen, eng.manifest.max_seq, eng.manifest.vocab);
    let tokens: Vec<i32> = (0..b * t).map(|i| (i % v) as i32).collect();
    let logits = eng.policy_logits(&policy, &tokens).unwrap();
    assert_eq!(logits.len(), b * t * v);
    assert!(logits.iter().all(|x| x.is_finite()));
    // Logits must vary across vocab (not a constant output).
    let row = &logits[0..v];
    let spread = row.iter().cloned().fold(f32::MIN, f32::max)
        - row.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 1e-4, "degenerate logits");
}

#[test]
fn supervised_training_reduces_loss_via_pjrt() {
    let Some(eng) = engines("sparrow-xs") else { return };
    let spec = config::model("sparrow-xs").unwrap();
    let mut rng = Rng::new(2);
    let mut st = TrainState::init(&spec.layout, &mut rng);
    let (b, t) = (eng.manifest.b_train, eng.manifest.max_seq);
    // Supervised: gold completions, advantage 1.
    let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..b as u64)
        .map(|i| {
            let task = Task::from_prompt_id(i, Benchmark::Gsm8k);
            (task.prompt_tokens(), task.answer_tokens())
        })
        .collect();
    let batch = pack_batch(&pairs, b, t);
    let adv = vec![1.0f32; b];
    let first = eng
        .train_step(&mut st, &batch.tokens, &batch.gen_mask, &adv, 1e-2)
        .unwrap();
    let mut last = first;
    for _ in 0..7 {
        last = eng
            .train_step(&mut st, &batch.tokens, &batch.gen_mask, &adv, 1e-2)
            .unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(
        last < first * 0.9,
        "loss should fall on a fixed batch: {first} -> {last}"
    );
    assert_eq!(st.step, 8);
}

#[test]
fn small_lr_train_step_yields_sparse_bf16_delta() {
    // The paper's Figure 3 measurement, end to end through PJRT: one RL
    // step at lr=1e-6 changes ~1% of stored bf16 elements.
    let Some(eng) = engines("sparrow-xs") else { return };
    let spec = config::model("sparrow-xs").unwrap();
    let mut rng = Rng::new(3);
    let mut st = TrainState::init(&spec.layout, &mut rng);
    let (b, t) = (eng.manifest.b_train, eng.manifest.max_seq);
    let pairs: Vec<(Vec<i32>, Vec<i32>)> = (0..b as u64)
        .map(|i| {
            let task = Task::from_prompt_id(i, Benchmark::Gsm8k);
            (task.prompt_tokens(), task.answer_tokens())
        })
        .collect();
    let batch = pack_batch(&pairs, b, t);
    let adv: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let old_policy = st.to_policy();
    eng.train_step(&mut st, &batch.tokens, &batch.gen_mask, &adv, 1e-6)
        .unwrap();
    let new_policy = st.to_policy();
    let delta = extract_delta(
        &spec.layout,
        &old_policy,
        &new_policy,
        0,
        1,
        sparrowrl::delta::ApplyMode::Assign,
    );
    let rho = delta.density(&spec.layout);
    assert!(rho > 0.0, "something must change");
    assert!(rho < 0.10, "rho={rho:.4} not sparse");
    eprintln!("measured rho at lr=1e-6: {:.4}%", rho * 100.0);
}

#[test]
fn generation_emits_tokens_and_respects_shape() {
    let Some(eng) = engines("sparrow-xs") else { return };
    let spec = config::model("sparrow-xs").unwrap();
    let mut rng = Rng::new(4);
    let st = TrainState::init(&spec.layout, &mut rng);
    let policy = st.to_policy();
    let prompts: Vec<Vec<i32>> = (0..4u64)
        .map(|i| Task::from_prompt_id(i, Benchmark::Gsm8k).prompt_tokens())
        .collect();
    let gens = generate_batch(
        &eng,
        &policy,
        &prompts,
        SampleCfg { temperature: 0.9, max_new_tokens: 6 },
        &mut rng,
    )
    .unwrap();
    assert_eq!(gens.len(), 4);
    for (g, p) in gens.iter().zip(&prompts) {
        assert_eq!(g.prompt_len, p.len());
        assert!(g.tokens.len() > g.prompt_len, "generated at least one token");
        assert!(g.tokens.len() <= g.prompt_len + 6 || g.tokens.last() == Some(&EOS));
        assert_eq!(&g.tokens[..g.prompt_len], p.as_slice());
    }
}

#[test]
fn delta_diff_artifact_agrees_with_host_scan() {
    let Some(eng) = engines("sparrow-xs") else { return };
    if !eng.has_delta_diff() {
        eprintln!("SKIP: delta_diff artifact missing");
        return;
    }
    let spec = config::model("sparrow-xs").unwrap();
    let mut rng = Rng::new(5);
    let st = TrainState::init(&spec.layout, &mut rng);
    let old = st.to_policy();
    let mut new = old.clone();
    // Flip a few stored values across tensors.
    let mut expected = 0i64;
    for tid in [0usize, 3, 5] {
        let t = &mut new.tensors[tid];
        let i = rng.range(0, t.len());
        t[i] = sparrowrl::util::Bf16::from_bits(t[i].to_bits() ^ 0x0001);
        expected += 1;
    }
    let (mask, nnz) = eng.delta_diff(&old, &new).unwrap();
    assert_eq!(nnz, expected, "Pallas kernel nnz");
    // Host scan agreement.
    let delta = extract_delta(
        &spec.layout,
        &old,
        &new,
        0,
        1,
        sparrowrl::delta::ApplyMode::Assign,
    );
    assert_eq!(delta.nnz() as i64, nnz);
    assert_eq!(mask.iter().filter(|&&m| m != 0).count() as i64, nnz);
}
