//! Failure injection across the fault-tolerance machinery (§5.4):
//! dropped/duplicated/reordered segments, actor crashes, relay crashes,
//! link partitions — the invariant under test is always the same: no
//! stale rollout is ever accepted, no prompt is lost, and surviving
//! actors absorb orphaned work without global stalls.

use sparrowrl::actor::{CommitResult, PolicyState};
use sparrowrl::config::{regions, GpuClass};
use sparrowrl::data::Benchmark;
use sparrowrl::delta::{extract_delta, ApplyMode, DeltaCheckpoint, ModelLayout, ParamSet};
use sparrowrl::ledger::{JobLedger, LeasePolicy, Reject};
use sparrowrl::sim::{self, RegionSpec, SimConfig, System};
use sparrowrl::transport::relay::RelayNode;
use sparrowrl::transport::{split_into_segments, Reassembler, Segment};
use sparrowrl::util::{prop, Bf16, Rng};

fn setup_delta(seed: u64) -> (ModelLayout, ParamSet, ParamSet, DeltaCheckpoint) {
    let layout = ModelLayout::transformer("t", 128, 32, 2, 64);
    let mut rng = Rng::new(seed);
    let p0 = ParamSet::random(&layout, 0.02, &mut rng);
    let mut p1 = p0.clone();
    for t in &mut p1.tensors {
        for _ in 0..10 {
            let i = rng.range(0, t.len());
            t[i] = Bf16::from_bits(t[i].to_bits() ^ 0x0044);
        }
    }
    let ckpt = DeltaCheckpoint::seal(&extract_delta(&layout, &p0, &p1, 0, 1, ApplyMode::Assign));
    (layout, p0, p1, ckpt)
}

#[test]
fn segment_loss_blocks_commit_retransmit_recovers() {
    let (layout, p0, p1, ckpt) = setup_delta(1);
    let segs = split_into_segments(1, &ckpt.bytes, 128);
    let mut st = PolicyState::new(layout, p0, 0);
    // Drop every 5th segment on "first transmission".
    for (i, s) in segs.iter().enumerate() {
        if i % 5 != 0 {
            st.on_segment(s.clone()).unwrap();
        }
    }
    assert!(!st.is_staged(1), "incomplete staging must not complete");
    assert_eq!(st.commit(1), CommitResult::NotStaged, "commit refused");
    // Retransmit everything (duplicates included) — idempotent recovery.
    for s in &segs {
        st.on_segment(s.clone()).unwrap();
    }
    assert!(st.is_staged(1));
    assert_eq!(st.commit(1), CommitResult::Applied);
    assert_eq!(st.params(), &p1);
}

#[test]
fn prop_random_loss_duplication_reordering_never_corrupts() {
    prop::check("chaotic transport never corrupts staging", 25, |rng| {
        let (layout, p0, p1, ckpt) = setup_delta(rng.next_u64());
        let segs = split_into_segments(1, &ckpt.bytes, 64 + rng.range(0, 200));
        let mut st = PolicyState::new(layout, p0, 0);
        // Build a chaotic schedule: each segment sent 0-3 times, shuffled.
        let mut schedule: Vec<Segment> = Vec::new();
        for s in &segs {
            for _ in 0..rng.range(0, 4) {
                schedule.push(s.clone());
            }
        }
        rng.shuffle(&mut schedule);
        for s in schedule {
            st.on_segment(s).unwrap();
        }
        // Final pass guarantees completeness.
        for s in &segs {
            st.on_segment(s.clone()).unwrap();
        }
        assert!(st.is_staged(1));
        assert_eq!(st.commit(1), CommitResult::Applied);
        assert_eq!(st.params(), &p1, "bit-exact despite chaos");
    });
}

#[test]
fn relay_crash_peers_fetch_directly() {
    let (_layout, _p0, _p1, ckpt) = setup_delta(3);
    let segs = split_into_segments(1, &ckpt.bytes, 100);
    // Relay forwards half the stream, then crashes.
    let mut relay = RelayNode::new(1);
    let mut peers: Vec<Vec<Segment>> = vec![Vec::new()];
    for s in segs.iter().take(segs.len() / 2) {
        relay.on_segment(s.clone(), &mut peers).unwrap();
    }
    drop(relay); // crash
    // Peer falls back to fetching from the Trainer (§5.4): it already has
    // the forwarded prefix; the direct path supplies the rest.
    let mut reasm = Reassembler::new(1);
    for s in peers[0].drain(..) {
        reasm.accept(s).unwrap();
    }
    assert!(!reasm.is_complete());
    for s in &segs {
        reasm.accept(s.clone()).unwrap(); // direct fetch (dups tolerated)
    }
    assert!(reasm.is_complete());
    let recovered = reasm.into_checkpoint().unwrap().unwrap();
    assert_eq!(recovered.hash, ckpt.hash);
}

#[test]
fn partitioned_actor_leases_expire_and_work_migrates() {
    let mut ledger = JobLedger::new(LeasePolicy { multiplier: 2.0, min_s: 10.0, max_s: 60.0, ..Default::default() });
    ledger.post(0..20);
    let h = [1u8; 32];
    // Actor 1 (about to be partitioned) claims half the pool.
    let claimed = ledger.issue(1, 5, h, 0.0, 10);
    assert_eq!(claimed.len(), 10);
    let claimed2 = ledger.issue(2, 5, h, 0.0, 10);
    assert_eq!(claimed2.len(), 10);
    // Actor 2 completes; actor 1 is partitioned (silent).
    for p in &claimed2 {
        ledger.submit(2, *p, 5, h, 5.0).unwrap();
    }
    // Lease expiry returns actor 1's prompts.
    let returned = ledger.expire(25.0);
    assert_eq!(returned.len(), 10);
    // Actor 2 absorbs the orphaned work.
    let migrated = ledger.issue(2, 5, h, 26.0, 10);
    assert_eq!(migrated.len(), 10);
    for p in &migrated {
        ledger.submit(2, *p, 5, h, 30.0).unwrap();
    }
    assert_eq!(ledger.stats().completed, 20);
    // The partitioned actor reconnects and submits its stale work: every
    // submission is rejected (lease gone).
    for p in &claimed {
        assert_eq!(ledger.submit(1, *p, 5, h, 31.0), Err(Reject::UnknownLease));
    }
}

#[test]
fn stale_version_and_wrong_hash_rollouts_rejected() {
    let mut ledger = JobLedger::new(LeasePolicy::default());
    ledger.post([1, 2]);
    let h5 = [5u8; 32];
    let p = ledger.issue(1, 5, h5, 0.0, 2);
    // Behaviour version mismatch (actor generated on v4).
    assert_eq!(ledger.submit(1, p[0], 4, h5, 1.0), Err(Reject::VersionMismatch));
    // Checkpoint hash mismatch (actor applied a corrupt/forked delta).
    assert_eq!(ledger.submit(1, p[1], 5, [6u8; 32], 1.0), Err(Reject::HashMismatch));
    assert_eq!(ledger.stats().completed, 0);
}

#[test]
fn sim_actor_failures_at_every_step_still_complete() {
    // Kill a different actor at every step; the batch must always
    // complete with bounded slowdown and full token accounting.
    let model = sparrowrl::config::model("qwen3-8b").unwrap();
    let regions = vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; 6])];
    let mut cfg = SimConfig::paper_testbed(model, Benchmark::Gsm8k, System::Sparrow, regions);
    cfg.steps = 5;
    cfg.failures = (0..5)
        .map(|s| sparrowrl::sim::driver::FailureEvent { actor: s as usize, step: s })
        .collect();
    let chaotic = sim::driver::run(&cfg);
    cfg.failures.clear();
    let healthy = sim::driver::run(&cfg);
    assert_eq!(chaotic.total_gen_tokens, healthy.total_gen_tokens);
    assert!(chaotic.total_time < healthy.total_time * 6.0, "no unbounded stall");
}

#[test]
fn out_of_order_delta_versions_never_apply() {
    let (layout, p0, p1, _c1) = setup_delta(7);
    // Build v2 on top of v1, deliver v2 first.
    let mut rng = Rng::new(17);
    let mut p2 = p1.clone();
    let t0 = &mut p2.tensors[0];
    let i = rng.range(0, t0.len());
    t0[i] = Bf16::from_bits(t0[i].to_bits() ^ 1);
    let c1 = DeltaCheckpoint::seal(&extract_delta(&layout, &p0, &p1, 0, 1, ApplyMode::Assign));
    let c2 = DeltaCheckpoint::seal(&extract_delta(&layout, &p1, &p2, 1, 2, ApplyMode::Assign));
    let mut st = PolicyState::new(layout, p0, 0);
    st.stage_checkpoint(c2.clone());
    // v2 cannot apply on v0 (base mismatch).
    assert!(matches!(st.commit(2), CommitResult::BaseMismatch { .. }));
    // After v1 arrives, the chain applies in order.
    st.stage_checkpoint(c1);
    assert_eq!(st.commit_chain(), 2);
    assert_eq!(st.active_version(), 2);
    assert_eq!(st.params(), &p2);
}
