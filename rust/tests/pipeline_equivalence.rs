//! Pipelined-vs-sequential equivalence: the overlapped one-step executor
//! must be a pure *scheduling* change. With deterministic virtual time,
//! the same seed must produce identical committed policies, identical
//! per-step rho / payload bytes, and the same final version under both
//! executors — and the runtime's internal bit-exactness assertion (actor
//! policy == trainer policy at every committed version) must hold across
//! threads. Runs through the Session API on the synthetic compute
//! backend, so no PJRT artifacts are needed.

use sparrowrl::delta::ModelLayout;
use sparrowrl::metrics::SpanKind;
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{RunSpec, Session};
use std::time::Duration;

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-eq", 256, 64, 2, 128)
}

fn config(n_actors: usize, steps: u64, seed: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(n_actors)
        .steps(steps)
        .sft_steps(3)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2) // large enough that every step flips bf16 bits
        .segment_bytes(256) // many segments per delta: real mid-gen staging
        .seed(seed)
        .deterministic()
}

fn run(spec: &RunSpec, comp: &SyntheticCompute, mode: ExecMode) -> RunReport {
    let plan = spec.clone().mode(mode).build().expect("valid spec");
    Session::start_with_compute(&plan, layout(), comp.clone())
        .expect("start session")
        .join()
        .unwrap_or_else(|e| panic!("{} run failed: {e:#}", mode.name()))
}

fn assert_equivalent(seq: &RunReport, pip: &RunReport) {
    assert_eq!(seq.final_version, pip.final_version, "final version");
    assert_eq!(seq.sft_losses, pip.sft_losses, "sft warmup identical");
    assert_eq!(seq.steps.len(), pip.steps.len());
    for (a, b) in seq.steps.iter().zip(&pip.steps) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.rho, b.rho, "step {} rho", a.step);
        assert_eq!(a.payload_bytes, b.payload_bytes, "step {} payload", a.step);
        assert_eq!(a.gen_tokens, b.gen_tokens, "step {} gen tokens", a.step);
        assert_eq!(a.mean_reward, b.mean_reward, "step {} reward", a.step);
        assert_eq!(a.loss, b.loss, "step {} loss", a.step);
        assert_eq!(
            a.policy_checksum, b.policy_checksum,
            "step {}: committed policies must be bit-identical across executors",
            a.step
        );
    }
}

#[test]
fn pipelined_matches_sequential_bitwise() {
    let comp = SyntheticCompute::new(16, 8, 64);
    let cfg = config(2, 4, 7);
    let seq = run(&cfg, &comp, ExecMode::Sequential);
    let pip = run(&cfg, &comp, ExecMode::Pipelined);
    assert_eq!(seq.final_version, 4);
    assert!(seq.steps.iter().all(|s| s.rho > 0.0), "every step changed the policy");
    assert!(seq.steps.iter().all(|s| s.payload_bytes > 0));
    assert_equivalent(&seq, &pip);
}

#[test]
fn equivalence_holds_across_actor_counts_and_seeds() {
    for (n_actors, seed) in [(1usize, 1u64), (3, 11), (4, 42)] {
        let comp = SyntheticCompute::new(16, 8, 64);
        let cfg = config(n_actors, 3, seed);
        let seq = run(&cfg, &comp, ExecMode::Sequential);
        let pip = run(&cfg, &comp, ExecMode::Pipelined);
        assert_equivalent(&seq, &pip);
    }
}

#[test]
fn pipelined_runs_are_self_reproducible() {
    // Thread interleavings must not leak into results even between two
    // pipelined runs (the stronger form of the determinism contract).
    let comp = SyntheticCompute::new(16, 8, 64);
    let cfg = config(3, 3, 5);
    let a = run(&cfg, &comp, ExecMode::Pipelined);
    let b = run(&cfg, &comp, ExecMode::Pipelined);
    assert_equivalent(&a, &b);
}

#[test]
fn different_seeds_actually_diverge() {
    // Guards against the equivalence tests passing vacuously (e.g. a
    // constant checksum): distinct seeds must produce distinct policies.
    let comp = SyntheticCompute::new(16, 8, 64);
    let a = run(&config(2, 3, 1), &comp, ExecMode::Pipelined);
    let b = run(&config(2, 3, 2), &comp, ExecMode::Pipelined);
    assert_ne!(
        a.steps.last().unwrap().policy_checksum,
        b.steps.last().unwrap().policy_checksum
    );
}

#[test]
fn pipelined_executor_overlaps_generation_with_sync() {
    // With emulated compute latencies, the pipelined run must actually
    // hide trainer sync time inside the generation window, and the
    // sequential reference must hide none.
    let comp = SyntheticCompute::new(16, 8, 64)
        .with_delays(Duration::from_millis(10), Duration::from_millis(8));
    // Real clocks (no .deterministic()): this is a timing property.
    let cfg = RunSpec::synthetic()
        .actors(2)
        .steps(4)
        .sft_steps(3)
        .group_size(2)
        .max_new_tokens(5)
        .lr_rl(1e-2)
        .segment_bytes(256)
        .seed(3);
    let sync = [SpanKind::Train, SpanKind::Extract];
    let seq = run(&cfg, &comp, ExecMode::Sequential);
    let pip = run(&cfg, &comp, ExecMode::Pipelined);
    assert_eq!(seq.timeline.overlap_ratio("trainer", &sync), 0.0, "sequential hides nothing");
    assert!(
        pip.timeline.overlap_ratio("trainer", &sync) > 0.0,
        "pipelined run recorded no overlap between rollout and train/extract spans"
    );
    // Both executors recorded the full span complement.
    for r in [&seq, &pip] {
        assert!(r.timeline.total("trainer", SpanKind::Train) > 0.0);
        assert!(r.timeline.total("trainer", SpanKind::Extract) > 0.0);
        assert!(r.timeline.total("actor0", SpanKind::Rollout) > 0.0);
    }
}
