//! Hot-path benches: delta extraction scan, codec encode/decode, the fused
//! streaming pipeline, and scatter-assign apply — the per-step CPU costs of
//! §5.1/§5.2. Targets (DESIGN.md §8): scan >= 1 GB/s/core, apply >= 2 GB/s,
//! fused single-pass >= 1.5x the seed's extract_delta + encode_delta
//! sequence at rho=1%.
//!
//! Emits `BENCH_encoding.json` (cwd) on the harness result schema
//! (`bench::summary`): timings as ungated gauges, the seeded-RNG payload
//! bytes and nnz as gated `Lower` metrics, diffable with
//! `sparrowrl bench compare`. Set `BENCH_QUICK=1` for a quick local run
//! (small model, few reps).

use sparrowrl::delta::{
    apply_delta, decode_delta, encode_delta, extract_delta, naive, ApplyMode,
    DeltaStreamApplier, DeltaStreamDecoder, DeltaStreamEncoder, ModelLayout, ParamSet,
    StreamConfig,
};
use sparrowrl::bench::{Better, ResultRecord, ResultSet};
use sparrowrl::util::bench::Bencher;
use sparrowrl::util::{prop, Bf16, Rng};

fn perturbed(p: &ParamSet, rho: f64, rng: &mut Rng) -> ParamSet {
    let mut q = p.clone();
    for t in &mut q.tensors {
        let n = t.len();
        let k = ((n as f64 * rho) as usize).max(1);
        for i in prop::sparse_indices(rng, n as u64, k.min(n)) {
            let v = &mut t[i as usize];
            *v = Bf16::from_bits(v.to_bits() ^ 0x0040);
        }
    }
    q
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 9) };
    let layout = if quick {
        ModelLayout::transformer("bench-quick", 2048, 256, 4, 1024)
    } else {
        ModelLayout::transformer("bench", 8192, 512, 8, 2048)
    };
    let mut rng = Rng::new(42);
    println!(
        "model: {} params ({} dense bf16){}",
        layout.total_params(),
        sparrowrl::util::fmt_bytes(layout.dense_bytes_bf16()),
        if quick { " [quick]" } else { "" }
    );
    let old = ParamSet::random(&layout, 0.02, &mut rng);
    let new = perturbed(&old, 0.01, &mut rng);
    let dense = layout.dense_bytes_bf16();

    // ---- seed pipeline: three sequential full-materialization passes ----
    b.bench_bytes("extract_delta scan (rho=1%)", 2 * dense, || {
        std::hint::black_box(extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign));
    });

    b.bench_bytes("extract_delta_parallel (8 threads)", 2 * dense, || {
        std::hint::black_box(sparrowrl::delta::extract_delta_parallel(
            &layout, &old, &new, 0, 1, ApplyMode::Assign, 8,
        ));
    });

    let delta = extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign);
    let bytes = encode_delta(&delta);
    println!(
        "delta: nnz={} payload={} ({}x under dense)",
        delta.nnz(),
        sparrowrl::util::fmt_bytes(bytes.len() as u64),
        dense / bytes.len() as u64
    );

    b.bench_bytes("encode_delta (varint+hash)", bytes.len() as u64, || {
        std::hint::black_box(encode_delta(&delta));
    });
    // The seed's wire path, end to end: extract then encode (two passes).
    let two_pass = b
        .bench_bytes("extract + encode (seed two-pass)", 2 * dense, || {
            let d = extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign);
            std::hint::black_box(encode_delta(&d));
        })
        .median;

    // ---- fused streaming pipeline -------------------------------------
    let enc = DeltaStreamEncoder::new(&layout, 0, 1, ApplyMode::Assign, StreamConfig::default());
    let pool = enc.pool();
    let fused = b
        .bench_bytes("stream fused extract+encode+segment", 2 * dense, || {
            enc.encode(&old, &new, |seg| {
                pool.recycle(std::hint::black_box(seg).payload);
            });
        })
        .median;
    let fused_par = b
        .bench_bytes("stream fused, parallel (8 threads)", 2 * dense, || {
            enc.encode_parallel(&old, &new, 8, |seg| {
                pool.recycle(std::hint::black_box(seg).payload);
            });
        })
        .median;
    let speedup = two_pass.as_secs_f64() / fused.as_secs_f64().max(1e-12);
    println!(
        "fused single-pass speedup vs seed two-pass: {speedup:.2}x (target >= 1.5x), \
         parallel {:.2}x",
        two_pass.as_secs_f64() / fused_par.as_secs_f64().max(1e-12)
    );

    b.bench_bytes("decode_delta (verify+parse)", bytes.len() as u64, || {
        std::hint::black_box(decode_delta(&bytes).unwrap());
    });
    let (segs, _) = enc.encode_to_segments(&old, &new);
    b.bench_bytes("stream decode (per-segment parse)", bytes.len() as u64, || {
        let mut dec = DeltaStreamDecoder::new(1);
        for s in &segs {
            dec.push(s.clone()).unwrap();
        }
        std::hint::black_box(dec.into_staged().unwrap());
    });
    b.bench_bytes("encode_naive (int32 baseline)", bytes.len() as u64, || {
        std::hint::black_box(naive::encode_naive(&delta, &layout));
    });

    // Scatter-assign apply on actor-resident parameters.
    let mut params = old.clone();
    b.bench_bytes("apply_delta scatter-assign", delta.nnz() * 2, || {
        apply_delta(&mut params, &delta);
    });
    // Scatter-assign is idempotent, so one pre-cloned ParamSet can absorb
    // the stream every iteration — the timed region is parse+scatter, not
    // a dense-model memcpy.
    let mut p_stream = old.clone();
    b.bench_bytes("stream apply (per-segment scatter)", delta.nnz() * 2, || {
        let mut ap = DeltaStreamApplier::new(1);
        for s in &segs {
            ap.push(s.clone(), &mut p_stream).unwrap();
        }
        std::hint::black_box(ap.applied_nnz());
    });

    // Density sweep: how codec rates move with rho (Figure 10's regime).
    for rho in [0.001, 0.01, 0.03, 0.1] {
        let new = perturbed(&old, rho, &mut rng);
        let d = extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign);
        let enc_bytes = encode_delta(&d);
        println!(
            "rho={rho:<6} nnz={:<9} bytes/nnz={:.2}",
            d.nnz(),
            enc_bytes.len() as f64 / d.nnz() as f64
        );
    }

    // Harness-schema emit: the seeded delta's byte counts are gated
    // (deterministic across machines); every timing stays a gauge.
    let mut set = ResultSet::from_bencher("bench-encoding", &b);
    set.push(
        ResultRecord::new("bench-encoding/derived")
            .gate("delta_payload_bytes", bytes.len() as f64, Better::Lower)
            .gate("delta_nnz", delta.nnz() as f64, Better::Exact)
            .gauge("fused_speedup_vs_two_pass", speedup),
    );
    let out = std::path::Path::new("BENCH_encoding.json");
    set.write(out).expect("write BENCH_encoding.json");
    println!("bench results written to {}", out.display());
}
