//! Hot-path benches: delta extraction scan, codec encode/decode, and
//! scatter-assign apply — the per-step CPU costs of §5.1/§5.2.
//! Targets (DESIGN.md §8): scan >= 1 GB/s/core, apply >= 2 GB/s.

use sparrowrl::delta::{
    apply_delta, decode_delta, encode_delta, extract_delta, naive, ApplyMode, ModelLayout,
    ParamSet,
};
use sparrowrl::util::bench::Bencher;
use sparrowrl::util::{prop, Bf16, Rng};

fn perturbed(p: &ParamSet, rho: f64, rng: &mut Rng) -> ParamSet {
    let mut q = p.clone();
    for t in &mut q.tensors {
        let n = t.len();
        let k = ((n as f64 * rho) as usize).max(1);
        for i in prop::sparse_indices(rng, n as u64, k.min(n)) {
            let v = &mut t[i as usize];
            *v = Bf16::from_bits(v.to_bits() ^ 0x0040);
        }
    }
    q
}

fn main() {
    let mut b = Bencher::new(2, 9);
    let layout = ModelLayout::transformer("bench", 8192, 512, 8, 2048);
    let mut rng = Rng::new(42);
    println!(
        "model: {} params ({} dense bf16)",
        layout.total_params(),
        sparrowrl::util::fmt_bytes(layout.dense_bytes_bf16())
    );
    let old = ParamSet::random(&layout, 0.02, &mut rng);
    let new = perturbed(&old, 0.01, &mut rng);
    let dense = layout.dense_bytes_bf16();

    // Extraction scan (bit-compare + compact), the paper's ~5 s / 16 GB.
    b.bench_bytes("extract_delta scan (rho=1%)", 2 * dense, || {
        std::hint::black_box(extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign));
    });

    b.bench_bytes("extract_delta_parallel (8 threads)", 2 * dense, || {
        std::hint::black_box(sparrowrl::delta::extract_delta_parallel(
            &layout, &old, &new, 0, 1, ApplyMode::Assign, 8,
        ));
    });

    let delta = extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign);
    let bytes = encode_delta(&delta);
    println!(
        "delta: nnz={} payload={} ({}x under dense)",
        delta.nnz(),
        sparrowrl::util::fmt_bytes(bytes.len() as u64),
        dense / bytes.len() as u64
    );

    b.bench_bytes("encode_delta (varint+hash)", bytes.len() as u64, || {
        std::hint::black_box(encode_delta(&delta));
    });
    b.bench_bytes("decode_delta (verify+parse)", bytes.len() as u64, || {
        std::hint::black_box(decode_delta(&bytes).unwrap());
    });
    b.bench_bytes("encode_naive (int32 baseline)", bytes.len() as u64, || {
        std::hint::black_box(naive::encode_naive(&delta, &layout));
    });

    // Scatter-assign apply on actor-resident parameters.
    let mut params = old.clone();
    b.bench_bytes("apply_delta scatter-assign", delta.nnz() * 2, || {
        apply_delta(&mut params, &delta);
    });

    // Density sweep: how codec rates move with rho (Figure 10's regime).
    for rho in [0.001, 0.01, 0.03, 0.1] {
        let new = perturbed(&old, rho, &mut rng);
        let d = extract_delta(&layout, &old, &new, 0, 1, ApplyMode::Assign);
        let enc = encode_delta(&d);
        println!(
            "rho={rho:<6} nnz={:<9} bytes/nnz={:.2}",
            d.nnz(),
            enc.len() as f64 / d.nnz() as f64
        );
    }
}
