//! Model-registry benches (PR 10), three tiers:
//!
//! 1. Dedup ratio: N fine-tunes published off one shared SFT base —
//!    logical bytes (every model's base + fold, counted per model) vs
//!    physical bytes in the content-addressed pool. The base must be
//!    stored exactly once no matter how many runs publish it.
//! 2. Swap payload: the composed hot-swap delta between two published
//!    fine-tunes vs the dense snapshot a registry-less retarget would
//!    ship — the paper's bandwidth argument applied to serving.
//! 3. Swap makespan: wall clock of composing + applying the swap delta
//!    (the actor-visible retarget latency, network excluded).
//!
//! Emits `BENCH_registry.json`. Set `BENCH_QUICK=1` for a quick run.

use sparrowrl::bench::{Better, ResultRecord, ResultSet};
use sparrowrl::delta::{apply_delta, policy_witness, DurableStore, ModelLayout, ModelRegistry};
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{RunSpec, Session};
use sparrowrl::util::bench::Bencher;

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-registry-bench", 512, 128, 2, 256)
}

/// Every fine-tune shares the seed + SFT config (identical base policy)
/// and differs in RL step count (distinct chains).
fn spec(steps: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(2)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .segment_bytes(4 << 10)
        .seed(67)
        .deterministic()
}

fn run(spec: RunSpec) -> RunReport {
    let plan = spec.mode(ExecMode::Sequential).build().expect("valid spec");
    Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session")
        .join()
        .expect("session run")
}

fn dir_size(dir: &std::path::Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| rd.filter_map(|e| e.ok()).filter_map(|e| e.metadata().ok()).map(|m| m.len()).sum())
        .unwrap_or(0)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_models: u64 = if quick { 3 } else { 5 };
    let mut b = Bencher::new(1, if quick { 2 } else { 3 });
    let mut derived: Vec<(String, f64, Better)> = Vec::new();
    let scratch =
        std::env::temp_dir().join(format!("sprw-bench-registry-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch).expect("scratch dir");
    let reg_dir = scratch.join("registry");
    let l = layout();

    // -- 1. publish N fine-tunes off one base, measure dedup -------------
    for i in 0..n_models {
        let run_dir = scratch.join(format!("run{i}"));
        run(spec(2 + i).persist_dir(&run_dir).publish_to(&reg_dir, &format!("ft-{i}")));
    }
    let reg = ModelRegistry::open(&reg_dir).unwrap_or_else(|e| panic!("open registry: {e}"));
    assert_eq!(reg.models().len(), n_models as usize);
    let base_objects = reg.bases().len();
    // Logical bytes: what N independent single-run stores would hold for
    // base + folded artifact; physical: the shared pool on disk.
    let logical: u64 = reg
        .models()
        .values()
        .map(|m| {
            reg.bases()[&m.base].bytes + m.versions.iter().map(|v| v.payload_bytes).sum::<u64>()
        })
        .sum();
    let physical = dir_size(&reg_dir.join("objects"));
    let dedup_ratio = logical as f64 / physical.max(1) as f64;
    println!(
        "dedup: {n_models} fine-tunes, {base_objects} base object(s), logical {} -> pool {} \
         ({dedup_ratio:.2}x)",
        sparrowrl::util::fmt_bytes(logical),
        sparrowrl::util::fmt_bytes(physical),
    );
    assert_eq!(base_objects, 1, "N fine-tunes off one base must store the base once");
    derived.push(("base_objects_stored".into(), base_objects as f64, Better::Exact));
    derived.push(("registry_pool_bytes".into(), physical as f64, Better::Lower));
    derived.push(("dedup_ratio".into(), dedup_ratio, Better::Higher));

    // -- 2. swap payload vs dense snapshot -------------------------------
    let (src, tgt) = (("ft-0", 2u64), (format!("ft-{}", n_models - 1), n_models + 1));
    let composed = reg
        .compose_swap(&l, (src.0, src.1), (&tgt.0, tgt.1))
        .unwrap_or_else(|e| panic!("compose swap: {e}"));
    let payload = sparrowrl::delta::encode_delta(&composed).len() as u64;
    let snapshot = l.total_params() * 2;
    assert!(payload < snapshot, "swap payload {payload} must beat dense snapshot {snapshot}");
    println!(
        "swap {}@v{} -> {}@v{}: payload {} vs dense snapshot {} ({:.1}x smaller)",
        src.0,
        src.1,
        tgt.0,
        tgt.1,
        sparrowrl::util::fmt_bytes(payload),
        sparrowrl::util::fmt_bytes(snapshot),
        snapshot as f64 / payload.max(1) as f64,
    );
    derived.push(("swap_payload_bytes".into(), payload as f64, Better::Lower));
    derived.push(("dense_snapshot_bytes".into(), snapshot as f64, Better::Lower));
    derived
        .push(("swap_reduction".into(), snapshot as f64 / payload.max(1) as f64, Better::Higher));

    // -- 3. swap makespan (compose + apply, witness-checked) -------------
    let store = DurableStore::open(&scratch.join("run0")).expect("recover source run");
    let actor_policy = store.reconstruct(&l, src.1).expect("reconstruct source");
    let want = reg.witness(&tgt.0, tgt.1).expect("target witness");
    let swap_s = b
        .bench("swap compose + apply", || {
            let d = reg
                .compose_swap(&l, (src.0, src.1), (&tgt.0, tgt.1))
                .unwrap_or_else(|e| panic!("compose swap: {e}"));
            let mut p = actor_policy.clone();
            apply_delta(&mut p, &d);
            assert_eq!(policy_witness(&p), want, "swap diverged from published witness");
            std::hint::black_box(p);
        })
        .median
        .as_secs_f64();
    println!("swap makespan (compose + apply): {:.1} ms", swap_s * 1e3);
    derived.push(("swap_makespan_s".into(), swap_s, Better::Lower));

    let _ = std::fs::remove_dir_all(&scratch);
    // Harness-schema emit: byte counts and object counts are
    // deterministic (gated); timings are machine-dependent gauges.
    let mut set = ResultSet::from_bencher("bench-registry", &b);
    let mut rec = ResultRecord::new("bench-registry/derived");
    for (k, v, better) in &derived {
        rec = if k.ends_with("_s") { rec.gauge(k, *v) } else { rec.gate(k, *v, *better) };
    }
    set.push(rec);
    let out = std::path::Path::new("BENCH_registry.json");
    set.write(out).expect("write bench json");
    println!("bench results written to {}", out.display());
}
