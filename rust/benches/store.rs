//! Durable-store benches (PR 7), three tiers:
//!
//! 1. Durability tax: wall clock of the same deterministic run with and
//!    without a durable store attached — the per-commit price of
//!    sealing objects (tmp + fsync + rename) plus the journal append.
//! 2. Reconstruct latency: materializing the final policy by replaying
//!    the full delta chain vs applying the compacted (folded) chain.
//! 3. Compaction ratio: encoded bytes of `D_1..D_k` vs the single
//!    folded object (lossless — verified against the journaled witness).
//!
//! Emits `BENCH_store.json`. Set `BENCH_QUICK=1` for a quick local run.

use sparrowrl::bench::{Better, ResultRecord, ResultSet};
use sparrowrl::delta::{policy_witness, DurableStore, ModelLayout};
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{RunSpec, Session};
use sparrowrl::util::bench::Bencher;

fn layout() -> ModelLayout {
    ModelLayout::transformer("syn-store-bench", 512, 128, 2, 256)
}

fn spec(steps: u64) -> RunSpec {
    RunSpec::synthetic()
        .actors(2)
        .steps(steps)
        .sft_steps(2)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .segment_bytes(4 << 10)
        .seed(61)
        .deterministic()
}

fn run(spec: RunSpec) -> RunReport {
    let plan = spec.mode(ExecMode::Sequential).build().expect("valid spec");
    Session::start_with_compute(&plan, layout(), SyntheticCompute::new(16, 8, 64))
        .expect("start session")
        .join()
        .expect("session run")
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let steps: u64 = if quick { 5 } else { 12 };
    let mut b = Bencher::new(1, if quick { 2 } else { 3 });
    let mut derived: Vec<(String, f64)> = Vec::new();
    let scratch = std::env::temp_dir().join(format!("sprw-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch).expect("scratch dir");

    // -- 1. durability tax per committed step ----------------------------
    let plain_s = b
        .bench("run, no durability", || {
            std::hint::black_box(run(spec(steps)));
        })
        .median
        .as_secs_f64();
    let mut rep = 0u32;
    let persist_s = b
        .bench("run, durable store", || {
            rep += 1;
            // A fresh directory per rep: a durable store refuses to be
            // re-seeded by a second fresh run.
            let dir = scratch.join(format!("rep{rep}"));
            std::hint::black_box(run(spec(steps).persist_dir(&dir)));
        })
        .median
        .as_secs_f64();
    let tax_per_step = (persist_s - plain_s).max(0.0) / steps as f64;
    println!(
        "durability tax: {plain_s:.3}s plain vs {persist_s:.3}s durable \
         ({:.1} ms per committed step)",
        tax_per_step * 1e3
    );
    derived.push(("plain_run_s".into(), plain_s));
    derived.push(("durable_run_s".into(), persist_s));
    derived.push(("journal_seal_tax_per_step_s".into(), tax_per_step));

    // -- 2 + 3. reconstruct latency and compaction ratio -----------------
    let dir = scratch.join("main");
    let report = run(spec(steps).persist_dir(&dir));
    let l = layout();
    let mut store = DurableStore::open(&dir).unwrap_or_else(|e| panic!("recover: {e}"));
    let witness = report.steps.last().expect("run committed steps").policy_checksum;
    let chain_s = b
        .bench("reconstruct final, chain replay", || {
            let p = store.reconstruct(&l, steps).unwrap_or_else(|e| panic!("reconstruct: {e}"));
            std::hint::black_box(p);
        })
        .median
        .as_secs_f64();
    let stats = store.compact(&l, None).unwrap_or_else(|e| panic!("compact: {e}"));
    assert_eq!(stats.upto, steps);
    let compacted_s = b
        .bench("reconstruct final, compacted", || {
            let p = store.reconstruct(&l, steps).unwrap_or_else(|e| panic!("reconstruct: {e}"));
            std::hint::black_box(p);
        })
        .median
        .as_secs_f64();
    // Lossless by construction: the compacted path must reproduce the
    // live run's committed checksum exactly.
    let p = store.reconstruct(&l, steps).unwrap_or_else(|e| panic!("reconstruct: {e}"));
    assert_eq!(policy_witness(&p), witness, "compacted reconstruct diverged from the live run");
    assert!(
        stats.compacted_bytes <= stats.chain_bytes,
        "folding D_1..D_{steps} must not grow the artifact"
    );
    println!(
        "compaction: chain {} -> folded {} ({:.1}%), reconstruct {:.3}s -> {:.3}s",
        sparrowrl::util::fmt_bytes(stats.chain_bytes),
        sparrowrl::util::fmt_bytes(stats.compacted_bytes),
        stats.compacted_bytes as f64 / stats.chain_bytes as f64 * 100.0,
        chain_s,
        compacted_s,
    );
    derived.push(("chain_bytes".into(), stats.chain_bytes as f64));
    derived.push(("compacted_bytes".into(), stats.compacted_bytes as f64));
    derived.push((
        "compaction_ratio".into(),
        stats.compacted_bytes as f64 / stats.chain_bytes as f64,
    ));
    derived.push(("reconstruct_chain_s".into(), chain_s));
    derived.push(("reconstruct_compacted_s".into(), compacted_s));
    derived.push(("reconstruct_speedup".into(), chain_s / compacted_s.max(1e-12)));

    let _ = std::fs::remove_dir_all(&scratch);
    // Harness-schema emit: chain/compacted byte counts are deterministic
    // (gated `Lower`); durability-tax and reconstruct timings are gauges.
    let mut set = ResultSet::from_bencher("bench-store", &b);
    let mut rec = ResultRecord::new("bench-store/derived");
    for (k, v) in &derived {
        rec = if k.ends_with("_bytes") { rec.gate(k, *v, Better::Lower) } else { rec.gauge(k, *v) };
    }
    set.push(rec);
    let out = std::path::Path::new("BENCH_store.json");
    set.write(out).expect("write bench json");
    println!("bench results written to {}", out.display());
}
