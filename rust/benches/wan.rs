//! WAN distribution bench: CPU cost of the distribution-tree hot paths
//! (plan construction, striped arrival-order simulation, relay
//! cut-through fanout) plus the analytic WAN makespan record — striped
//! relay tree vs single-stream direct per-actor fan-out on the `wan-4`
//! preset — written to `BENCH_wan.json` so the distribution layer's perf
//! trajectory is tracked across PRs. Set `BENCH_QUICK=1` for a quick
//! local run.

use sparrowrl::bench::{Better, ResultRecord, ResultSet};
use sparrowrl::config::{self, wan_preset};
use sparrowrl::data::Benchmark;
use sparrowrl::netsim::deliver_striped;
use sparrowrl::sim::compute::{delta_payload_bytes, ComputeModel};
use sparrowrl::transport::relay::RelayNode;
use sparrowrl::transport::{split_into_segments, DistributionPlan, Segment};
use sparrowrl::util::bench::Bencher;
use sparrowrl::util::Rng;

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = Bencher::new(if quick { 1 } else { 2 }, if quick { 5 } else { 11 });

    let preset = wan_preset("wan-4").unwrap();
    b.bench("DistributionPlan::from_preset (wan-4)", || {
        std::hint::black_box(DistributionPlan::from_preset(&preset, 1 << 20));
    });
    let plan = DistributionPlan::from_preset(&preset, 1 << 20);

    // Arrival-order simulation over the widest-striped leg.
    let n_segs = if quick { 64 } else { 256 };
    let sizes = vec![1u64 << 20; n_segs];
    let widest = plan
        .legs
        .iter()
        .max_by_key(|l| l.streams)
        .expect("wan-4 has legs");
    b.bench(
        &format!("netsim striped arrivals ({n_segs} x 1 MiB, {} stripes)", widest.streams),
        || {
            let mut rng = Rng::new(1);
            std::hint::black_box(deliver_striped(&widest.wan, &sizes, widest.streams, &mut rng));
        },
    );

    // Relay cut-through fanout of a pseudo-delta through the whole tree.
    let mb = if quick { 4 } else { 16 };
    let mut rng = Rng::new(2);
    let payload_bytes: Vec<u8> = (0..mb << 20).map(|_| rng.next_u64() as u8).collect();
    let segs = split_into_segments(1, &payload_bytes, 1 << 20);
    let total: u64 = plan.legs.iter().map(|_| payload_bytes.len() as u64).sum();
    b.bench_bytes(&format!("relay tree fanout (wan-4, {mb} MiB/region)"), total, || {
        for leg in &plan.legs {
            let mut relay = RelayNode::new(1);
            let mut peers: Vec<Vec<Segment>> = vec![Vec::new(); leg.peers.len()];
            for s in &segs {
                relay.on_segment(s.clone(), &mut peers).unwrap();
            }
            std::hint::black_box(peers);
        }
    });

    // Analytic WAN record: the acceptance metric behind `exp wan`.
    let model = config::model("qwen3-8b").unwrap();
    let payload = delta_payload_bytes(&model, model.expected_rho);
    let cm = ComputeModel::new(Benchmark::Gsm8k, 4);
    let produce = Some(cm.stream_emit_bps(&model, payload));
    let mut rng = Rng::new(0);
    let striped = plan.makespan(payload, produce, &mut rng);
    let direct = plan.direct_single_stream_makespan(payload, produce, &mut rng);
    println!(
        "wan-4 qwen3-8b delta {}: striped relay tree {striped:.2}s vs \
         1-stream direct fan-out {direct:.2}s ({:.1}x)",
        sparrowrl::util::fmt_bytes(payload),
        direct / striped.max(1e-9),
    );
    assert!(
        striped < direct,
        "striped distribution must beat single-stream direct fan-out"
    );
    // Harness-schema emit. The analytic record is seeded and therefore
    // deterministic: the payload is gated `Lower` and the makespans and
    // speedup gate the WAN model's trajectory; CPU timings stay gauges.
    let mut set = ResultSet::from_bencher("bench-wan", &b);
    let mut rec = ResultRecord::new("bench-wan/derived")
        .gate("payload_bytes", payload as f64, Better::Lower)
        .gate("striped_makespan_s", striped, Better::Lower)
        .gate("direct_single_stream_makespan_s", direct, Better::Lower)
        .gate("wan_speedup", direct / striped.max(1e-9), Better::Higher);
    const UTIL_KEYS: [&str; 4] = ["util_r0", "util_r1", "util_r2", "util_r3"];
    for (i, (region, util)) in plan.region_utilization(payload, striped).iter().enumerate() {
        println!("  {region}: {:.0}% WAN utilization over the makespan", util * 100.0);
        rec = rec.gauge(UTIL_KEYS[i], *util);
    }
    set.push(rec);
    let out = std::path::Path::new("BENCH_wan.json");
    set.write(out).expect("write bench json");
    println!("bench results written to {}", out.display());
}
