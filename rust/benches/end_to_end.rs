//! End-to-end benches: the discrete-event simulator itself (it must sweep
//! Fig 8/12/13 campaigns in seconds) and one full paper-testbed run per
//! system for the record.

use sparrowrl::config::{self, regions, GpuClass};
use sparrowrl::data::Benchmark;
use sparrowrl::sim::driver::{run, SimConfig};
use sparrowrl::sim::{RegionSpec, System};
use sparrowrl::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new(2, 9);
    let model = config::model("qwen3-8b").unwrap();
    for sys in System::all() {
        let fleet = vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; 8])];
        let mut cfg = SimConfig::paper_testbed(model.clone(), Benchmark::Gsm8k, sys, fleet);
        cfg.steps = 7;
        b.bench(&format!("sim 7-step run [{}]", sys.name()), || {
            std::hint::black_box(run(&cfg));
        });
    }
    // A full Figure-8-style campaign: 3 benchmarks x 3 models x 4 systems.
    b.bench("fig8 campaign (36 runs)", || {
        for bench in Benchmark::all() {
            for m in config::paper_models() {
                for sys in System::all() {
                    let model = config::model(m).unwrap();
                    let n = ((model.total_params() as f64 / 1.02e9).round() as usize).clamp(4, 16);
                    let fleet = vec![RegionSpec::new(regions::CANADA, vec![GpuClass::A100; n])];
                    let cfg = SimConfig::paper_testbed(model, bench, sys, fleet);
                    std::hint::black_box(run(&cfg));
                }
            }
        }
    });
}
