//! End-to-end overlap bench: the phase-sequential executor vs the
//! overlapped one-step pipelined executor on the same RL loop, plus the
//! measured overlap efficiency (hidden-sync-time / sync-time) from the
//! pipelined run's timeline. Emits `BENCH_pipeline.json` on the harness
//! result schema (`bench::summary`; all metrics are timing gauges, so
//! nothing gates) so the perf trajectory is tracked across PRs.
//!
//! Runs through the Session API (`RunSpec` -> `Session` -> `join`) on
//! the deterministic synthetic engine with emulated compute latencies
//! (artifact-free, CI-safe). When PJRT artifacts for sparrow-xs are
//! present, the real loop is measured as well. Set `BENCH_QUICK=1` for a
//! quick local run.

use sparrowrl::bench::{ResultRecord, ResultSet};
use sparrowrl::delta::ModelLayout;
use sparrowrl::metrics::SpanKind;
use sparrowrl::rt::{ExecMode, RunReport, SyntheticCompute};
use sparrowrl::session::{RunSpec, Session};
use sparrowrl::util::bench::Bencher;
use std::time::Duration;

const SYNC: [SpanKind; 2] = [SpanKind::Train, SpanKind::Extract];

fn synthetic_spec(quick: bool, mode: ExecMode) -> RunSpec {
    RunSpec::synthetic()
        .steps(if quick { 5 } else { 10 })
        .sft_steps(0)
        .actors(2)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .mode(mode)
}

fn run_synthetic(quick: bool, mode: ExecMode) -> RunReport {
    let plan = synthetic_spec(quick, mode).build().expect("valid spec");
    let layout = ModelLayout::transformer("syn-bench", 512, 128, 2, 256);
    let comp = SyntheticCompute::new(16, 8, 64)
        .with_delays(Duration::from_millis(10), Duration::from_millis(8));
    Session::start_with_compute(&plan, layout, comp)
        .expect("start session")
        .join()
        .expect("session run")
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = Bencher::new(1, if quick { 3 } else { 7 });
    let mut derived: Vec<(&str, f64)> = Vec::new();

    // -- synthetic engine: emulated accelerator latencies ----------------
    let seq = b
        .bench("e2e 2-actor synthetic [sequential]", || {
            std::hint::black_box(run_synthetic(quick, ExecMode::Sequential));
        })
        .median
        .as_secs_f64();
    let pip = b
        .bench("e2e 2-actor synthetic [pipelined]", || {
            std::hint::black_box(run_synthetic(quick, ExecMode::Pipelined));
        })
        .median
        .as_secs_f64();
    let speedup = seq / pip.max(1e-12);
    // Overlap efficiency from a representative pipelined timeline.
    let report = run_synthetic(quick, ExecMode::Pipelined);
    let sync_s = report.timeline.total("trainer", SpanKind::Train)
        + report.timeline.total("trainer", SpanKind::Extract);
    let overlap = report.timeline.overlap_ratio("trainer", &SYNC);
    println!(
        "synthetic: sequential {seq:.3}s, pipelined {pip:.3}s -> {speedup:.2}x; \
         hidden sync {:.0}% of {:.3}s",
        overlap * 100.0,
        sync_s
    );
    derived.push(("sequential_wall_s", seq));
    derived.push(("pipelined_wall_s", pip));
    derived.push(("pipeline_speedup", speedup));
    derived.push(("overlap_efficiency", overlap));
    derived.push(("hidden_sync_s", overlap * sync_s));

    // -- real PJRT loop, when artifacts exist ----------------------------
    let model = "sparrow-xs";
    if sparrowrl::runtime::artifacts_dir()
        .join(format!("{model}_policy_fwd.hlo.txt"))
        .exists()
    {
        let real = |mode: ExecMode| -> RunReport {
            let plan = RunSpec::model(model)
                .steps(if quick { 3 } else { 6 })
                .sft_steps(0)
                .mode(mode)
                .build()
                .expect("valid spec");
            Session::start(&plan).expect("start session").join().expect("session run")
        };
        let seq = b
            .bench("e2e 2-actor sparrow-xs [sequential]", || {
                std::hint::black_box(real(ExecMode::Sequential));
            })
            .median
            .as_secs_f64();
        let pip = b
            .bench("e2e 2-actor sparrow-xs [pipelined]", || {
                std::hint::black_box(real(ExecMode::Pipelined));
            })
            .median
            .as_secs_f64();
        let real_speedup = seq / pip.max(1e-12);
        let report = real(ExecMode::Pipelined);
        println!(
            "sparrow-xs: sequential {seq:.3}s, pipelined {pip:.3}s -> {real_speedup:.2}x"
        );
        derived.push(("real_sequential_wall_s", seq));
        derived.push(("real_pipelined_wall_s", pip));
        derived.push(("real_pipeline_speedup", real_speedup));
        derived.push((
            "real_overlap_efficiency",
            report.timeline.overlap_ratio("trainer", &SYNC),
        ));
    } else {
        eprintln!("({model} artifacts missing; real-loop case skipped)");
    }

    // Harness-schema emit: wall clocks and ratios are machine-dependent,
    // so every derived metric stays an ungated gauge.
    let mut set = ResultSet::from_bencher("bench-pipeline", &b);
    let mut rec = ResultRecord::new("bench-pipeline/derived");
    for (k, v) in &derived {
        rec = rec.gauge(k, *v);
    }
    set.push(rec);
    let out = std::path::Path::new("BENCH_pipeline.json");
    set.write(out).expect("write bench json");
    println!("bench results written to {}", out.display());
}
