//! Coordinator-logic benches: Algorithm-1 allocation and the Job Ledger's
//! issue/submit/expire cycle at fleet scale.

use sparrowrl::ledger::{JobLedger, LeasePolicy};
use sparrowrl::scheduler::{Scheduler, SchedulerConfig, VersionState};
use sparrowrl::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new(2, 11);

    for n_actors in [8usize, 64, 512] {
        let mut s = Scheduler::new(SchedulerConfig::default());
        for i in 0..n_actors as u32 {
            s.register(i, 1000.0 + i as f64);
            s.observe_version(i, VersionState { active: 1, staged: None });
        }
        b.bench(&format!("allocate B=4096 across {n_actors} actors"), || {
            std::hint::black_box(s.allocate(1, 4096));
        });
    }

    let mut b2 = Bencher::new(2, 11);
    b2.bench("ledger cycle: 4096 issue+submit+expire", || {
        let mut l = JobLedger::new(LeasePolicy::default());
        l.post(0..4096u64);
        let h = [0u8; 32];
        let got = l.issue(1, 1, h, 0.0, 4096);
        for p in got {
            l.submit(1, p, 1, h, 1.0).unwrap();
        }
        std::hint::black_box(l.expire(100.0));
    });
}
