//! Elastic-membership benches (ISSUE 6), three tiers:
//!
//! 1. Bootstrap cost: bytes on the wire to bring a joiner to the active
//!    version — the stored delta chain `D_1..D_v` (lossless sparse
//!    deltas, the paper's wire format) vs a dense policy snapshot. The
//!    asserted bound is the PR's acceptance criterion: the chain must be
//!    measurably cheaper.
//! 2. Makespan under preemption: the same deterministic Tcp run healthy
//!    and with a spot-preemption (no usable warning) mid-run — the price
//!    of a reissue-path recovery in wall clock.
//! 3. Autoscaler trace: tokens-per-dollar decisions emitted per version
//!    boundary by the cost-model policy.
//!
//! Emits `BENCH_elastic.json`. Set `BENCH_QUICK=1` for a quick local run.

use sparrowrl::bench::{Better, ResultRecord, ResultSet};
use sparrowrl::delta::ModelLayout;
use sparrowrl::rt::{BootstrapKind, RunReport, SyntheticCompute};
use sparrowrl::session::{Backend, Event, RunSpec, Session};
use sparrowrl::transport::{KillMode, KillSpec, TcpConfig};
use sparrowrl::util::bench::Bencher;
use std::time::Duration;

fn base_spec(quick: bool) -> RunSpec {
    RunSpec::synthetic()
        .steps(if quick { 4 } else { 8 })
        .sft_steps(0)
        .actors(3)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .segment_bytes(4 << 10)
        .deterministic()
        .pipelined()
}

fn run_collect(spec: &RunSpec) -> (Vec<Event>, RunReport) {
    let plan = spec.clone().build().expect("valid spec");
    let layout = ModelLayout::transformer("syn-el-bench", 512, 128, 2, 256);
    let comp = SyntheticCompute::new(16, 8, 64)
        .with_delays(Duration::from_millis(8), Duration::from_millis(6));
    let mut session =
        Session::start_with_compute(&plan, layout, comp).expect("start session");
    let mut events = Vec::new();
    while let Some(ev) = session.recv() {
        events.push(ev);
    }
    (events, session.join().expect("session run"))
}

/// Wire bytes of the single scripted join in `events`.
fn joined_bytes(events: &[Event]) -> u64 {
    events
        .iter()
        .find_map(|ev| match ev {
            Event::Joined { bytes, .. } => Some(*bytes),
            _ => None,
        })
        .expect("run admitted a joiner")
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = Bencher::new(1, if quick { 2 } else { 3 });
    let base = base_spec(quick);
    let mut derived: Vec<(String, f64)> = Vec::new();

    // -- 1. bootstrap bytes: delta chain vs dense snapshot ---------------
    // Both joiners target the same boundary, so the byte counts compare
    // the formats, not the targets.
    let join_v = 2;
    let (chain_ev, chain_report) =
        run_collect(&base.clone().join_at(3, join_v, BootstrapKind::DeltaChain));
    let (snap_ev, snap_report) =
        run_collect(&base.clone().join_at(3, join_v, BootstrapKind::Snapshot));
    assert_eq!(chain_report.joins, 1);
    assert_eq!(snap_report.joins, 1);
    let chain_bytes = joined_bytes(&chain_ev);
    let snap_bytes = joined_bytes(&snap_ev);
    println!(
        "bootstrap to v{join_v}: delta chain {} vs snapshot {} ({:.1}% of dense)",
        sparrowrl::util::fmt_bytes(chain_bytes),
        sparrowrl::util::fmt_bytes(snap_bytes),
        chain_bytes as f64 / snap_bytes as f64 * 100.0,
    );
    // Acceptance bound: replaying the lossless sparse chain must beat
    // shipping the dense policy.
    assert!(
        chain_bytes < snap_bytes,
        "delta-chain bootstrap ({chain_bytes} B) not cheaper than snapshot ({snap_bytes} B)"
    );
    derived.push(("bootstrap_chain_bytes".into(), chain_bytes as f64));
    derived.push(("bootstrap_snapshot_bytes".into(), snap_bytes as f64));
    derived.push((
        "bootstrap_chain_over_snapshot".into(),
        chain_bytes as f64 / snap_bytes as f64,
    ));

    // -- 2. makespan under spot preemption (Tcp, reissue path) -----------
    let tcp = |kills: Vec<KillSpec>| {
        base.clone()
            .wall_leases()
            .transport(Backend::Tcp(TcpConfig { streams: 2, bits_per_s: None, kills }))
    };
    let healthy_spec = tcp(vec![]);
    let preempt_spec = tcp(vec![KillSpec {
        actor: 2,
        at_version: 1, // mid-run: survivors absorb the re-issued leases
        mode: KillMode::Preempt { warn_ms: 0 },
    }]);
    let healthy_wall = b
        .bench("e2e tcp healthy fleet", || {
            std::hint::black_box(run_collect(&healthy_spec));
        })
        .median
        .as_secs_f64();
    let preempt_wall = b
        .bench("e2e tcp spot-preempted", || {
            std::hint::black_box(run_collect(&preempt_spec));
        })
        .median
        .as_secs_f64();
    let (_, preempted) = run_collect(&preempt_spec);
    assert_eq!(preempted.failovers, 1);
    assert_eq!(preempted.preempts, 1);
    println!(
        "makespan: healthy {healthy_wall:.3}s, preempted {preempt_wall:.3}s ({:.2}x)",
        preempt_wall / healthy_wall.max(1e-12),
    );
    derived.push(("makespan_healthy_s".into(), healthy_wall));
    derived.push(("makespan_preempted_s".into(), preempt_wall));
    derived.push((
        "makespan_preempt_overhead".into(),
        preempt_wall / healthy_wall.max(1e-12),
    ));

    // -- 3. autoscaler tokens-per-dollar trace ---------------------------
    let (scale_ev, _) = run_collect(&base.clone().autoscale());
    let decisions: Vec<(u64, f64, f64, &'static str)> = scale_ev
        .iter()
        .filter_map(|ev| match ev {
            Event::Autoscale { version, decision } => Some((
                *version,
                decision.marginal_tpd(),
                decision.reserve_line(),
                decision.name(),
            )),
            _ => None,
        })
        .collect();
    assert!(!decisions.is_empty(), "autoscaler emitted no decisions");
    for (v, tpd, line, name) in &decisions {
        println!("autoscale @v{v}: {name} (marginal {tpd:.0} tok/$, line {line:.0})");
    }
    let mean_tpd =
        decisions.iter().map(|(_, tpd, _, _)| tpd).sum::<f64>() / decisions.len() as f64;
    derived.push(("autoscale_decisions".into(), decisions.len() as f64));
    derived.push(("autoscale_mean_marginal_tpd".into(), mean_tpd));
    derived.push(("autoscale_reserve_line".into(), decisions[0].2));

    // Harness-schema emit: bootstrap byte counts come out of the
    // deterministic run, so they gate `Lower`; wall clocks and the
    // autoscaler trace stay ungated gauges.
    let mut set = ResultSet::from_bencher("bench-elastic", &b);
    let mut rec = ResultRecord::new("bench-elastic/derived");
    for (k, v) in &derived {
        rec = if k.ends_with("_bytes") { rec.gate(k, *v, Better::Lower) } else { rec.gauge(k, *v) };
    }
    set.push(rec);
    let out = std::path::Path::new("BENCH_elastic.json");
    set.write(out).expect("write bench json");
    println!("bench results written to {}", out.display());
}
