//! Transport benches: segmentation, striping, reassembly throughput, and
//! relay forwarding — §5.2's per-checkpoint CPU overheads.

use sparrowrl::transport::relay::RelayNode;
use sparrowrl::transport::{split_into_segments, stripe_round_robin, Reassembler, Segment};
use sparrowrl::util::bench::Bencher;
use sparrowrl::util::Rng;

fn main() {
    let mut b = Bencher::new(2, 9);
    // A ~64 MB pseudo-checkpoint (sparrow-xl scale delta).
    let mut rng = Rng::new(1);
    let bytes: Vec<u8> = (0..64 << 20).map(|_| rng.next_u64() as u8).collect();
    let n = bytes.len() as u64;

    b.bench_bytes("split_into_segments (1 MiB)", n, || {
        std::hint::black_box(split_into_segments(1, &bytes, 1 << 20));
    });

    let segs = split_into_segments(1, &bytes, 1 << 20);
    b.bench_bytes("stripe_round_robin (4 streams)", n, || {
        std::hint::black_box(stripe_round_robin(segs.clone(), 4));
    });

    b.bench_bytes("segment wire framing", n, || {
        let mut total = 0usize;
        for s in &segs {
            total += s.to_wire().len();
        }
        std::hint::black_box(total);
    });

    let wires: Vec<Vec<u8>> = segs.iter().map(|s| s.to_wire()).collect();
    b.bench_bytes("segment parse + checksum", n, || {
        for w in &wires {
            std::hint::black_box(Segment::from_wire(w).unwrap());
        }
    });

    b.bench_bytes("reassembly (in order)", n, || {
        let mut r = Reassembler::new(1);
        for s in &segs {
            r.accept(s.clone()).unwrap();
        }
        std::hint::black_box(r.assemble().unwrap());
    });

    b.bench_bytes("relay forward to 3 peers", n, || {
        let mut relay = RelayNode::new(1);
        let mut peers = vec![Vec::new(), Vec::new(), Vec::new()];
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        std::hint::black_box(peers);
    });
}
