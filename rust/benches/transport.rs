//! Transport benches, two tiers:
//!
//! 1. Micro: segmentation, striping, reassembly throughput, relay
//!    forwarding — §5.2's per-checkpoint CPU overheads.
//! 2. Backend: the same deterministic pipelined RL run over each
//!    `transport::api` backend (InProc / Sim / Tcp loopback) through the
//!    Session API, measuring per-backend wall clock, per-step latency,
//!    and the sync-hidden overlap ratio. Emits `BENCH_transport.json`
//!    and asserts the throughput sanity bound: zero-copy InProc must not
//!    be slower than framed loopback Tcp.
//!
//! Set `BENCH_QUICK=1` for a quick local run.

use sparrowrl::bench::{ResultRecord, ResultSet};
use sparrowrl::config::regions;
use sparrowrl::delta::ModelLayout;
use sparrowrl::metrics::SpanKind;
use sparrowrl::netsim::Link;
use sparrowrl::rt::{RunReport, SyntheticCompute};
use sparrowrl::session::{Backend, RunSpec, Session};
use sparrowrl::transport::relay::RelayNode;
use sparrowrl::transport::{
    split_into_segments, stripe_round_robin, Reassembler, Segment, SimNetConfig, TcpConfig,
};
use sparrowrl::util::bench::Bencher;
use sparrowrl::util::Rng;
use std::time::Duration;

const SYNC: [SpanKind; 2] = [SpanKind::Train, SpanKind::Extract];

fn micro(b: &mut Bencher, quick: bool) {
    // A pseudo-checkpoint at sparrow-xl delta scale (smaller when quick).
    let mut rng = Rng::new(1);
    let total = if quick { 8 << 20 } else { 64 << 20 };
    let bytes: Vec<u8> = (0..total).map(|_| rng.next_u64() as u8).collect();
    let n = bytes.len() as u64;

    b.bench_bytes("split_into_segments (1 MiB)", n, || {
        std::hint::black_box(split_into_segments(1, &bytes, 1 << 20));
    });

    let segs = split_into_segments(1, &bytes, 1 << 20);
    b.bench_bytes("stripe_round_robin (4 streams)", n, || {
        std::hint::black_box(stripe_round_robin(segs.clone(), 4));
    });

    b.bench_bytes("segment wire framing", n, || {
        let mut total = 0usize;
        for s in &segs {
            total += s.to_wire().len();
        }
        std::hint::black_box(total);
    });

    let wires: Vec<Vec<u8>> = segs.iter().map(|s| s.to_wire()).collect();
    b.bench_bytes("segment parse + checksum", n, || {
        for w in &wires {
            std::hint::black_box(Segment::from_wire(w).unwrap());
        }
    });

    b.bench_bytes("reassembly (in order)", n, || {
        let mut r = Reassembler::new(1);
        for s in &segs {
            r.accept(s.clone()).unwrap();
        }
        std::hint::black_box(r.assemble().unwrap());
    });

    b.bench_bytes("relay forward to 3 peers", n, || {
        let mut relay = RelayNode::new(1);
        let mut peers = vec![Vec::new(), Vec::new(), Vec::new()];
        for s in &segs {
            relay.on_segment(s.clone(), &mut peers).unwrap();
        }
        std::hint::black_box(peers);
    });
}

fn backend_spec(quick: bool) -> RunSpec {
    RunSpec::synthetic()
        .steps(if quick { 4 } else { 8 })
        .sft_steps(0)
        .actors(2)
        .group_size(2)
        .max_new_tokens(6)
        .lr_rl(1e-2)
        .segment_bytes(4 << 10)
        .deterministic()
        .pipelined()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let mut b = Bencher::new(1, if quick { 3 } else { 7 });
    micro(&mut b, quick);

    // -- backend tier: identical run, three transports -------------------
    // Emulated accelerator latencies so the overlap ratio is meaningful.
    let base = backend_spec(quick);
    let steps = base.clone().build().unwrap().config().steps as f64;
    let run = |spec: &RunSpec| -> RunReport {
        let plan = spec.clone().build().expect("valid spec");
        let layout = ModelLayout::transformer("syn-tr-bench", 512, 128, 2, 256);
        let comp = SyntheticCompute::new(16, 8, 64)
            .with_delays(Duration::from_millis(8), Duration::from_millis(6));
        Session::start_with_compute(&plan, layout, comp)
            .expect("start session")
            .join()
            .expect("session run")
    };

    let backends: Vec<(&str, Backend)> = vec![
        ("inproc", Backend::InProc),
        (
            "sim",
            Backend::SimNet(SimNetConfig::single_region(
                2,
                Link::from_profile(&regions::CANADA),
                4,
                0,
            )),
        ),
        (
            "tcp",
            Backend::Tcp(TcpConfig { streams: 2, bits_per_s: None, kills: vec![] }),
        ),
    ];
    let mut derived: Vec<(String, f64)> = Vec::new();
    let mut walls: Vec<(&str, f64)> = Vec::new();
    for (name, kind) in backends {
        let spec = base.clone().transport(kind);
        let wall = b
            .bench(&format!("e2e 2-actor pipelined [{name}]"), || {
                std::hint::black_box(run(&spec));
            })
            .median
            .as_secs_f64();
        let report = run(&spec);
        let overlap = report.timeline.overlap_ratio("trainer", &SYNC);
        println!(
            "{name}: wall {wall:.3}s, {:.1} ms/step, hidden sync {:.0}%",
            wall * 1e3 / steps,
            overlap * 100.0
        );
        derived.push((format!("{name}_wall_s"), wall));
        derived.push((format!("{name}_step_latency_s"), wall / steps));
        derived.push((format!("{name}_overlap_efficiency"), overlap));
        walls.push((name, wall));
    }
    let inproc = walls.iter().find(|(n, _)| *n == "inproc").unwrap().1;
    let tcp = walls.iter().find(|(n, _)| *n == "tcp").unwrap().1;
    derived.push(("tcp_over_inproc_wall_ratio".to_string(), tcp / inproc.max(1e-12)));
    // Sanity bound: zero-copy in-process must not lose to framed loopback
    // sockets (generous 1.15x slack absorbs CI timer noise — the real
    // signal is catastrophic regressions, e.g. a blocking wait on the
    // socket path).
    assert!(
        inproc <= tcp * 1.15,
        "InProc ({inproc:.3}s) slower than Tcp ({tcp:.3}s): transport overhead inverted"
    );

    // Harness-schema emit: per-backend wall clocks and ratios are
    // machine-dependent, so everything stays an ungated gauge (the hard
    // sanity bound is the assert above, not the compare gate).
    let mut set = ResultSet::from_bencher("bench-transport", &b);
    let mut rec = ResultRecord::new("bench-transport/derived");
    for (k, v) in &derived {
        rec = rec.gauge(k, *v);
    }
    set.push(rec);
    let out = std::path::Path::new("BENCH_transport.json");
    set.write(out).expect("write bench json");
    println!("bench results written to {}", out.display());
}
