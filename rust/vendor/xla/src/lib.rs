//! Compile-time stub for the `xla` (xla_extension / PJRT) bindings.
//!
//! The build container has no XLA shared library, so this crate provides
//! the exact API surface `sparrowrl::runtime` compiles against and returns
//! a descriptive runtime error from every entry point. Artifact-gated tests
//! check for `artifacts/*.hlo.txt` before constructing a client and
//! self-skip, so the stub keeps `cargo test` green while preserving the
//! full request-path code for environments with a real PJRT install.

use std::fmt;

/// Error type mirroring the binding's opaque status errors.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla runtime unavailable (offline stub); install xla_extension and swap \
         rust/vendor/xla for the real bindings to execute PJRT artifacts"
    ))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Bf16,
    F32,
    S32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

/// Host-side literal (stub: never actually constructed).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(unavailable("Literal::convert"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by execution (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_with_guidance() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("offline stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}
