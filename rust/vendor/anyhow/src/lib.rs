//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset SparrowRL uses: `Error`, `Result<T>`, the
//! `anyhow!` / `bail!` macros, and the `Context` extension trait for
//! `Result` and `Option`. The error is a chain of human-readable messages:
//! `Display` shows the outermost context, `{:#}` shows the full chain
//! joined with ": " (matching anyhow's alternate formatting), and `Debug`
//! shows the anyhow-style "Caused by:" listing.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a context message (the outermost description).
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which makes this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::Error::msg(format!($($t)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

/// Return early with an [`Error`] unless the condition holds (upstream
/// anyhow's `ensure!`, including the condition-only form).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            crate::ensure!(x > 0, "x must be positive, got {x}");
            crate::ensure!(x != 7);
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(format!("{}", check(-1).unwrap_err()), "x must be positive, got -1");
        assert!(format!("{}", check(7).unwrap_err()).contains("x != 7"));
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Err(anyhow!("always fails on {x}"))
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed (got 0)");
        assert_eq!(f(3).unwrap_err().to_string(), "always fails on 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert!(parse("12").is_ok());
        assert!(parse("nope").is_err());
    }
}
